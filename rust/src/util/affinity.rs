//! CPU affinity shim for run-pool worker pinning (`--pin-workers`).
//!
//! On Linux this calls `sched_setaffinity(2)` directly (declared here —
//! glibc is already linked by std, and no libc crate is vendored in the
//! offline image); everywhere else it compiles to a no-op that reports
//! pinning as unavailable. Pinning is strictly an opt-in wall-clock
//! stabilizer: simulated results are in virtual time and bit-identical
//! with or without it, so a failed or unsupported pin is never an error.

/// Pin the calling thread to one CPU, wrapping `cpu` modulo the number of
/// available CPUs. Returns whether the pin took effect (`false` on
/// unsupported platforms or if the syscall fails, e.g. under a restricted
/// cpuset).
#[cfg(target_os = "linux")]
pub fn pin_current_thread(cpu: usize) -> bool {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let cpu = cpu % n.max(1);
    // A 1024-bit cpu_set_t, the glibc default size.
    let mut mask = [0u64; 16];
    if cpu >= mask.len() * 64 {
        return false;
    }
    mask[cpu / 64] = 1u64 << (cpu % 64);
    // pid 0 = the calling thread.
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

/// Non-Linux platforms: pinning is unavailable; always `false`.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(cpu: usize) -> bool {
    let _ = cpu;
    false
}

/// Whether this build can pin threads at all (the `--pin-workers` smoke
/// asserts the flag degrades to a no-op elsewhere).
pub fn pinning_supported() -> bool {
    cfg!(target_os = "linux")
}

/// The machine's NUMA nodes as sorted CPU lists, read from
/// `/sys/devices/system/node/node*/cpulist` (kernel list format, e.g.
/// `0-3,8-11`). Empty off Linux, when sysfs is unavailable (containers
/// often mask it), or on any parse surprise — callers must treat empty as
/// "no topology known" and fall back to flat numbering.
#[cfg(target_os = "linux")]
pub fn node_cpulists() -> Vec<Vec<usize>> {
    let Ok(entries) = std::fs::read_dir("/sys/devices/system/node") else {
        return Vec::new();
    };
    let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
    for e in entries.flatten() {
        let name = e.file_name().into_string().unwrap_or_default();
        let Some(idx) = name.strip_prefix("node").and_then(|s| s.parse::<usize>().ok()) else {
            continue;
        };
        let Ok(text) = std::fs::read_to_string(e.path().join("cpulist")) else {
            continue;
        };
        let cpus = parse_cpulist(text.trim());
        if !cpus.is_empty() {
            nodes.push((idx, cpus));
        }
    }
    // read_dir order is arbitrary; node index order is the stable one.
    nodes.sort_by_key(|&(i, _)| i);
    nodes.into_iter().map(|(_, c)| c).collect()
}

/// Non-Linux platforms: no NUMA topology to read.
#[cfg(not(target_os = "linux"))]
pub fn node_cpulists() -> Vec<Vec<usize>> {
    Vec::new()
}

/// Parse the kernel's cpulist format: comma-separated CPUs and inclusive
/// ranges (`0-3,8-11,16`). Malformed fields are skipped rather than
/// failing the whole list — pinning is best-effort by contract.
fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for field in s.split(',') {
        let field = field.trim();
        if field.is_empty() {
            continue;
        }
        match field.split_once('-') {
            Some((a, b)) => {
                if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                    if a <= b && b - a < 4096 {
                        cpus.extend(a..=b);
                    }
                }
            }
            None => {
                if let Ok(c) = field.parse::<usize>() {
                    cpus.push(c);
                }
            }
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    cpus
}

/// The CPU run-pool worker `wid` should pin to: workers round-robin
/// across NUMA nodes (worker i → node i mod N, then walk that node's CPU
/// list), so a 2-worker pool on a 2-node machine lands one worker per
/// node instead of two hyperthread-adjacent CPUs on node 0. With fewer
/// than two known nodes (including off Linux) this is the identity —
/// exactly the historical flat numbering. Pinning placement only affects
/// wall-clock: results are in virtual time and bit-identical regardless.
pub fn worker_cpu(wid: usize) -> usize {
    worker_cpu_in(&node_cpulists(), wid)
}

fn worker_cpu_in(nodes: &[Vec<usize>], wid: usize) -> usize {
    if nodes.len() < 2 {
        return wid;
    }
    let node = &nodes[wid % nodes.len()];
    node[(wid / nodes.len()) % node.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_reports_platform_support() {
        // Pin from a scratch thread so the test runner's thread keeps its
        // original mask either way.
        let ok = std::thread::spawn(|| pin_current_thread(0)).join().unwrap();
        if !pinning_supported() {
            assert!(!ok, "non-Linux pinning must be a no-op");
        }
    }

    #[test]
    fn pin_wraps_out_of_range_cpus() {
        let ok = std::thread::spawn(|| pin_current_thread(usize::MAX - 7)).join().unwrap();
        assert_eq!(ok, std::thread::spawn(|| pin_current_thread(0)).join().unwrap());
    }

    #[test]
    fn cpulist_parses_ranges_singles_and_junk() {
        assert_eq!(parse_cpulist("0-3,8-11"), vec![0, 1, 2, 3, 8, 9, 10, 11]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist("2, 0-1 ,2"), vec![0, 1, 2]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("x,3-1,4"), vec![4]);
    }

    #[test]
    fn worker_cpus_round_robin_across_nodes() {
        // 2 nodes of 4 CPUs: even workers on node 0, odd on node 1,
        // walking each node's list as the pool outgrows the node count.
        let nodes = vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]];
        let got: Vec<usize> = (0..8).map(|w| worker_cpu_in(&nodes, w)).collect();
        assert_eq!(got, vec![0, 4, 1, 5, 2, 6, 3, 7]);
    }

    #[test]
    fn worker_cpus_flat_without_topology() {
        assert_eq!(worker_cpu_in(&[], 3), 3);
        assert_eq!(worker_cpu_in(&[vec![0, 1, 2, 3]], 2), 2);
    }

    #[test]
    fn node_cpulists_is_safe_to_call() {
        // Smoke: whatever sysfs says (or doesn't — containers often mask
        // it), every reported node must be a non-empty sorted CPU list.
        for node in node_cpulists() {
            assert!(!node.is_empty());
            assert!(node.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
