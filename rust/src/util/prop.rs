//! Minimal in-tree property-based testing.
//!
//! `proptest` is not vendored in this offline environment, so this module
//! provides the small subset the test-suite needs: seeded case generation,
//! a configurable number of cases, and panics that report the failing seed
//! so a case can be replayed deterministically.

use crate::util::rng::Rng;

/// Number of cases per property, overridable via `PROP_CASES`.
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` against `cases` RNGs derived from `seed`. Each case gets an
/// independent deterministic generator; a failure names the case seed.
pub fn for_all_with(seed: u64, cases: usize, mut prop: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed at case {case} (seed {case_seed:#x}): {msg}");
        }
    }
}

/// Run a property with the default number of cases.
pub fn for_all(seed: u64, prop: impl FnMut(&mut Rng)) {
    for_all_with(seed, default_cases(), prop);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_for_true_property() {
        for_all(1, |rng| {
            let x = rng.below(100);
            assert!(x < 100);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let result = std::panic::catch_unwind(|| {
            for_all_with(2, 32, |rng| {
                assert!(rng.below(10) < 5, "too big");
            });
        });
        let err = result.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("property failed at case"), "got: {msg}");
    }

    #[test]
    fn case_count_is_respected() {
        let mut n = 0;
        for_all_with(3, 17, |_| n += 1);
        assert_eq!(n, 17);
    }
}
