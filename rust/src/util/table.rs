//! ASCII table rendering for figure/table regeneration output.
//!
//! Every paper table and figure is re-emitted as an aligned text table (plus
//! CSV via [`crate::util::csv`]), matching the rows/series the paper reports.

/// A simple column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Render with per-column alignment (numbers right, text left).
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let numeric: Vec<bool> = (0..ncols)
            .map(|i| {
                !self.rows.is_empty()
                    && self.rows.iter().all(|r| {
                        let c = r[i].trim();
                        c.is_empty() || c.parse::<f64>().is_ok() || c == "-"
                    })
            })
            .collect();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String], out: &mut String| {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    if numeric[i] {
                        format!("{:>width$}", c, width = widths[i])
                    } else {
                        format!("{:<width$}", c, width = widths[i])
                    }
                })
                .collect();
            out.push_str("| ");
            out.push_str(&parts.join(" | "));
            out.push_str(" |\n");
        };
        fmt_row(&self.header, &mut out);
        out.push_str("|");
        for w in &widths {
            out.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }
}

/// Format an f64 with `prec` decimals, using "-" for NaN (absent cells).
pub fn num(x: f64, prec: usize) -> String {
    if x.is_nan() {
        "-".to_string()
    } else {
        format!("{x:.prec$}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut t = Table::new("demo", &["name", "ns"]);
        t.row_strs(&["L1", "1.17"]);
        t.row_strs(&["L2", "3.50"]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("| L1"));
        assert!(s.contains("1.17"));
    }

    #[test]
    fn numeric_columns_right_aligned() {
        let mut t = Table::new("", &["v"]);
        t.row_strs(&["1.0"]);
        t.row_strs(&["100.0"]);
        let s = t.render();
        assert!(s.contains("|   1.0 |"), "got:\n{s}");
    }

    #[test]
    #[should_panic]
    fn mismatched_row_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row_strs(&["only one"]);
    }

    #[test]
    fn num_formats() {
        assert_eq!(num(1.234, 2), "1.23");
        assert_eq!(num(f64::NAN, 2), "-");
    }
}
