//! A fast, non-cryptographic hasher for the simulator's hot maps
//! (coherence records, memory pages, prefetch sets). The default SipHash
//! showed up as the top cost in the engine profile (EXPERIMENTS.md §Perf);
//! this multiply-xor hasher (FxHash-style) is ~3× cheaper for u64 keys.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7C_C1_B7_27_22_0A_95;

/// FxHash-style hasher: rotate-xor-multiply per word.
#[derive(Default, Clone)]
pub struct FastHasher {
    hash: u64,
}

impl Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.hash = (self.hash.rotate_left(5) ^ b as u64).wrapping_mul(SEED);
        }
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.hash = (self.hash.rotate_left(5) ^ n).wrapping_mul(SEED);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.write_u64(n as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.write_u64(n as u64);
    }
}

pub type FastMap<K, V> = HashMap<K, V, BuildHasherDefault<FastHasher>>;
pub type FastSet<K> = HashSet<K, BuildHasherDefault<FastHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: FastMap<u64, u64> = FastMap::default();
        for i in 0..1000u64 {
            m.insert(i * 64, i);
        }
        for i in 0..1000u64 {
            assert_eq!(m.get(&(i * 64)), Some(&i));
        }
        assert_eq!(m.len(), 1000);
    }

    #[test]
    fn distinct_keys_distinct_hashes_mostly() {
        use std::hash::{BuildHasher, BuildHasherDefault};
        let bh: BuildHasherDefault<FastHasher> = Default::default();
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            seen.insert(bh.hash_one(i * 64));
        }
        assert_eq!(seen.len(), 10_000, "collisions on line-address keys");
    }

    #[test]
    fn set_works() {
        let mut s: FastSet<u64> = FastSet::default();
        s.insert(42);
        assert!(s.contains(&42));
        assert!(!s.contains(&43));
    }
}
