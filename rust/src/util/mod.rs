//! Small self-contained utilities.
//!
//! The build environment is fully offline with only the `xla` and `anyhow`
//! crates vendored, so the pieces one would normally pull from crates.io
//! (a PRNG, summary statistics, a property-testing helper, table/CSV
//! formatting, CLI parsing) are implemented here.

pub mod affinity;
pub mod cli;
pub mod fxhash;
pub mod csv;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
