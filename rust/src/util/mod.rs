//! Small self-contained utilities.
//!
//! The build environment is fully offline with only the `xla` and `anyhow`
//! crates vendored, so the pieces one would normally pull from crates.io
//! (a PRNG, summary statistics, a property-testing helper, table/CSV
//! formatting, CLI parsing) are implemented here.

pub mod affinity;
pub mod cli;
pub mod fxhash;
pub mod csv;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

/// Normalize a user-supplied token for label matching: lowercase with
/// every non-alphanumeric character stripped. All `FromStr` impls in the
/// crate (ops, states, levels, distances, architectures) match on this
/// form, so `"Shared L2"`, `"shared-l2"`, and `"sharedl2"` parse alike
/// and every `label()` output round-trips through its parser.
pub fn norm_token(s: &str) -> String {
    s.chars()
        .filter(|c| c.is_ascii_alphanumeric())
        .map(|c| c.to_ascii_lowercase())
        .collect()
}

#[cfg(test)]
mod norm_tests {
    use super::norm_token;

    #[test]
    fn strips_and_lowers() {
        assert_eq!(norm_token("Shared L2"), "sharedl2");
        assert_eq!(norm_token("shared-l2"), "sharedl2");
        assert_eq!(norm_token("shared L3 domain (other die)"), "sharedl3domainotherdie");
        assert_eq!(norm_token("CAS"), "cas");
    }
}
