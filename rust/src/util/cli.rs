//! Minimal CLI argument parsing (clap is not vendored in this offline image).
//!
//! Supports `repro <subcommand> [positional...] [--flag] [--key value]`.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positionals, and `--key [value]` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positionals.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.opt(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("figure 2 extra");
        assert_eq!(a.subcommand.as_deref(), Some("figure"));
        assert_eq!(a.positionals, vec!["2", "extra"]);
    }

    #[test]
    fn options_and_flags() {
        let a = parse("bench --arch haswell --verbose --scale=20");
        assert_eq!(a.opt("arch"), Some("haswell"));
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_parse("scale", 0u32), 20);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b");
        assert!(a.flag("a") && a.flag("b"));
    }

    #[test]
    fn default_when_missing() {
        let a = parse("x");
        assert_eq!(a.opt_parse("threads", 4usize), 4);
    }
}
