//! In-tree micro-benchmark harness (criterion is not vendored in this
//! offline image). Provides warm-up, repeated timed runs, and a
//! criterion-style report: mean ± stddev, median, min/max, throughput.
//!
//! Used by the `rust/benches/*.rs` targets (built with `harness = false`).

use crate::util::stats::Summary;
use std::time::Instant;

/// Configuration of a timing run.
#[derive(Debug, Clone, Copy)]
pub struct BenchCfg {
    pub warmup_iters: u32,
    pub sample_iters: u32,
}

impl Default for BenchCfg {
    fn default() -> Self {
        // fast deterministic workloads: modest samples suffice
        BenchCfg { warmup_iters: 2, sample_iters: 10 }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    /// Optional items-per-iteration for throughput reporting.
    pub items: Option<u64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.summary;
        let mut out = format!(
            "{:<44} {:>10.3} ms ±{:>8.3}  (median {:.3}, min {:.3}, max {:.3})",
            self.name,
            s.mean / 1e6,
            s.stddev / 1e6,
            s.median / 1e6,
            s.min / 1e6,
            s.max / 1e6,
        );
        if let Some(items) = self.items {
            let per_sec = items as f64 / (s.mean / 1e9);
            out.push_str(&format!("  [{:.2} Melem/s]", per_sec / 1e6));
        }
        out
    }
}

/// A group of benchmarks sharing a config, printed criterion-style.
pub struct Bencher {
    cfg: BenchCfg,
    pub results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new() -> Bencher {
        let cfg = match std::env::var("BENCH_FAST") {
            Ok(_) => BenchCfg { warmup_iters: 1, sample_iters: 3 },
            Err(_) => BenchCfg::default(),
        };
        Bencher { cfg, results: Vec::new() }
    }

    pub fn with_cfg(cfg: BenchCfg) -> Bencher {
        Bencher { cfg, results: Vec::new() }
    }

    /// Time `f` (called once per iteration); returns ns samples.
    pub fn bench(&mut self, name: impl Into<String>, mut f: impl FnMut()) -> &BenchResult {
        for _ in 0..self.cfg.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.cfg.sample_iters as usize);
        for _ in 0..self.cfg.sample_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let result = BenchResult {
            name: name.into(),
            summary: Summary::of(&samples),
            items: None,
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Like [`bench`] but reports `items`/iteration throughput.
    pub fn bench_throughput(
        &mut self,
        name: impl Into<String>,
        items: u64,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        for _ in 0..self.cfg.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.cfg.sample_iters as usize);
        for _ in 0..self.cfg.sample_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let result = BenchResult {
            name: name.into(),
            summary: Summary::of(&samples),
            items: Some(items),
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Header line for a bench group.
    pub fn group(&self, title: &str) {
        println!("\n=== {title} ===");
    }
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new()
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box wrapper,
/// kept for API parity with criterion).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut b = Bencher::with_cfg(BenchCfg { warmup_iters: 1, sample_iters: 4 });
        let mut n = 0u64;
        b.bench("count", || {
            n = black_box(n + 1);
        });
        assert_eq!(b.results.len(), 1);
        assert_eq!(b.results[0].summary.n, 4);
        assert_eq!(n, 5); // 1 warmup + 4 samples
    }

    #[test]
    fn throughput_reported() {
        let mut b = Bencher::with_cfg(BenchCfg { warmup_iters: 0, sample_iters: 2 });
        let r = b.bench_throughput("t", 1000, || {
            black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.items, Some(1000));
        assert!(r.report().contains("Melem/s"));
    }
}
