//! AMD Bulldozer testbed: 2× Opteron 6272 "Interlagos" (Cray XE6 Monte Rosa
//! node), 32 cores (Fig. 1b).
//!
//! Each socket hosts two 8-core dies connected with HyperTransport; modules
//! of two cores share a 2 MB L2; the 8 MB per-die L3 is *non-inclusive* and
//! partially consumed by the HT Assist probe filter. Write-through L1,
//! MOESI. The paper's case study in coherence-protocol pathologies: shared
//! -line atomics always broadcast invalidations to remote dies (§5.1.2).

use crate::sim::config::*;
use crate::sim::fabric::Fabric;
use crate::sim::mechanisms::Mechanisms;
use crate::sim::protocol::ProtocolKind;
use crate::sim::timing::{Level, LocalityClass, OpMatch, OverheadTable, StateClass, Timing};
use crate::sim::topology::Topology;
use crate::sim::writebuffer::WriteBufferCfg;

fn overheads() -> OverheadTable {
    OverheadTable::new()
        // §5.1.2: atomics take ≈20 ns longer than reads on *local* caches
        // (beyond E(A)=25, which already covers part of it) but only ≈8 ns
        // into caches of different cores: encode the local surcharge.
        .rule_any(OpMatch::AnyAtomic, Some(StateClass::ExclusiveLike), Some(Level::L2), Some(LocalityClass::Local), 8.0)
        .rule_any(OpMatch::AnyAtomic, Some(StateClass::ExclusiveLike), Some(Level::L3), Some(LocalityClass::Local), 6.0)
        // Remote accesses come in cheaper than the naive composition.
        .rule_any(OpMatch::AnyAtomic, None, Some(Level::L1), Some(LocalityClass::Remote), -8.0)
        .rule_any(OpMatch::AnyAtomic, None, Some(Level::L2), Some(LocalityClass::Remote), -8.0)
}

pub fn bulldozer() -> MachineConfig {
    MachineConfig {
        name: "Bulldozer",
        cpu_model: "Opteron 6272",
        // 32 cores: modules of 2 share L2; 8 cores per die; 2 dies/socket.
        topology: Topology::new(32, 2, 8, 2),
        // 16 KB write-through L1 per core (Table 1).
        l1: CacheGeom { size: 16 * 1024, ways: 4, write_policy: WritePolicy::WriteThrough },
        // 2 MB L2 per 2-core module.
        l2: CacheGeom { size: 2 << 20, ways: 16, write_policy: WritePolicy::WriteBack },
        // 8 MB non-inclusive L3 per die; HT Assist steals 1 MB (2/16 ways).
        l3: Some(CacheGeom { size: 8 << 20, ways: 16, write_policy: WritePolicy::WriteBack }),
        l3_policy: L3Policy::NonInclusive,
        protocol: ProtocolKind::Moesi,
        // Table 2, Bulldozer column.
        timing: Timing {
            r_l1: 5.2,
            r_l2: 8.8,
            r_l3: 30.0,
            hop: 62.0, // HyperTransport
            mem: 75.0,
            e_cas: 25.0,
            e_faa: 25.0,
            e_swp: 25.0,
            write_issue: 1.0,
        },
        overheads: overheads(),
        write_buffer: WriteBufferCfg { entries: 24, merging: true, fastlock: false },
        mechanisms: Mechanisms::ALL_OFF,
        ht_assist: Some(HtAssistCfg {
            reserved_ways: 2, // 1 MB of the 8 MB L3
            track_shared: false,
            shared_capacity: 0,
        }),
        muw: true, // §5.5: the MuW fast-migration state
        contended_write_combining: false, // §5.4: Bulldozer suffers
        // Fitted by `repro calibrate --arch bulldozer` against the Fig. 8
        // plateau targets (data::fig8_targets); see EXPERIMENTS.md. The
        // lowest of the four: HyperTransport hand-offs pipeline poorly,
        // and half the round-robin hand-offs are already cheap intra-
        // module SharedL2 transfers, so little overlap is left to claim.
        handoff_overlap: 0.22,
        // Scalar hand-off pricing by default; `--topology routed` opts
        // into the die-to-die HyperTransport fabric (sim::fabric).
        fabric: Fabric::Scalar,
        cas128_penalty: (20.0, 5.0), // §5.3
        unaligned: UnalignedCfg { bus_lock_ns: 560.0 },
        frequency_mhz: 2100,
        interconnect: "4x HT 3.1 (6.4 GT/s)",
        memory: "32GB",
    }
}

/// Bulldozer with the paper's §6.2 hardware proposals enabled:
/// MOESI+OL/SL states (§6.2.1) and HT Assist S/O tracking (§6.2.2).
/// Used by the ablation benchmarks to quantify the proposed wins.
pub fn bulldozer_with_extensions(olsl: bool, ht_tracking: bool, fastlock: bool) -> MachineConfig {
    let mut cfg = bulldozer();
    if olsl {
        cfg.name = "Bulldozer+OL/SL";
        cfg.protocol = ProtocolKind::MoesiOlSl;
    }
    if ht_tracking {
        cfg.name = if olsl { "Bulldozer+OL/SL+HTA" } else { "Bulldozer+HTA" };
        cfg.ht_assist = Some(HtAssistCfg {
            reserved_ways: 2,
            track_shared: true,
            shared_capacity: 16 * 1024, // 1 MB of 64 B entries
        });
    }
    if fastlock {
        cfg.write_buffer.fastlock = true;
    }
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_through_l1() {
        assert_eq!(bulldozer().l1.write_policy, WritePolicy::WriteThrough);
    }

    #[test]
    fn ht_assist_reserves_l3() {
        let c = bulldozer();
        assert_eq!(c.effective_l3_bytes(), Some(7 << 20));
    }

    #[test]
    fn module_shares_l2() {
        assert_eq!(bulldozer().l2_shared_by(), 2);
    }

    #[test]
    fn extensions_change_protocol() {
        let e = bulldozer_with_extensions(true, true, true);
        assert_eq!(e.protocol, ProtocolKind::MoesiOlSl);
        assert!(e.ht_assist.unwrap().track_shared);
        assert!(e.write_buffer.fastlock);
        // base stays MOESI
        assert_eq!(bulldozer().protocol, ProtocolKind::Moesi);
    }
}
