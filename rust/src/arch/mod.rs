//! The four evaluated architectures (§2.2, Table 1) encoded as
//! [`MachineConfig`]s, with timing parameters from Table 2 and O-residuals
//! from Table 3 (Haswell) / §5 (the other testbeds).

mod bulldozer;
mod haswell;
mod ivybridge;
mod xeonphi;

pub use bulldozer::{bulldozer, bulldozer_with_extensions};
pub use haswell::haswell;
pub use ivybridge::ivybridge;
pub use xeonphi::xeonphi;

use crate::sim::config::MachineConfig;

/// All four paper testbeds.
pub fn all() -> Vec<MachineConfig> {
    vec![haswell(), ivybridge(), bulldozer(), xeonphi()]
}

/// Look up a testbed by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<MachineConfig> {
    match name.to_ascii_lowercase().as_str() {
        "haswell" => Some(haswell()),
        "ivybridge" | "ivy" | "ivy-bridge" => Some(ivybridge()),
        "bulldozer" | "amd" => Some(bulldozer()),
        "xeonphi" | "phi" | "mic" | "xeon-phi" => Some(xeonphi()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::protocol::ProtocolKind;

    #[test]
    fn four_testbeds() {
        assert_eq!(all().len(), 4);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("haswell").unwrap().name, "Haswell");
        assert_eq!(by_name("IVY").unwrap().name, "Ivy Bridge");
        assert_eq!(by_name("amd").unwrap().name, "Bulldozer");
        assert_eq!(by_name("mic").unwrap().name, "Xeon Phi");
        assert!(by_name("alpha").is_none());
    }

    #[test]
    fn protocols_match_table1() {
        assert_eq!(haswell().protocol, ProtocolKind::Mesif);
        assert_eq!(ivybridge().protocol, ProtocolKind::Mesif);
        assert_eq!(bulldozer().protocol, ProtocolKind::Moesi);
        assert_eq!(xeonphi().protocol, ProtocolKind::MesiGols);
    }

    #[test]
    fn core_counts_match_table1() {
        assert_eq!(haswell().topology.n_cores, 4);
        assert_eq!(ivybridge().topology.n_cores, 24);
        assert_eq!(bulldozer().topology.n_cores, 32);
        assert_eq!(xeonphi().topology.n_cores, 61);
    }

    #[test]
    fn phi_has_no_l3() {
        assert!(!xeonphi().has_l3());
        assert!(haswell().has_l3());
    }

    #[test]
    fn table2_medians_encoded() {
        let h = haswell().timing;
        assert_eq!(h.r_l1, 1.17);
        assert_eq!(h.r_l2, 3.5);
        assert_eq!(h.r_l3, 10.3);
        assert_eq!(h.mem, 65.0);
        assert_eq!(h.e_cas, 4.7);
        let p = xeonphi().timing;
        assert_eq!(p.hop, 161.2);
        assert_eq!(p.e_cas, 12.4);
        assert_eq!(p.e_faa, 2.4);
    }
}
