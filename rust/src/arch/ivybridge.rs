//! Intel Ivy Bridge testbed: 2× Xeon E5-2697v2 (ETH Euler cluster), 24 cores
//! over two sockets connected with QPI.
//!
//! Private L1/L2, 30 MB shared inclusive L3 per socket with core-valid bits,
//! MESIF. The deep-memory-hierarchy / NUMA testbed.

use crate::atomics::OpKind;
use crate::sim::config::*;
use crate::sim::fabric::Fabric;
use crate::sim::mechanisms::Mechanisms;
use crate::sim::protocol::ProtocolKind;
use crate::sim::timing::{Level, LocalityClass, OpMatch, OverheadTable, StateClass, Timing};
use crate::sim::topology::Topology;
use crate::sim::writebuffer::WriteBufferCfg;

pub fn ivybridge() -> MachineConfig {
    let overheads = OverheadTable::new()
        // Same qualitative residuals as Haswell (both MESIF + inclusive L3).
        .rule(OpMatch::AnyAtomic, StateClass::ExclusiveLike, Level::L2, LocalityClass::Local, 3.6)
        .rule(OpMatch::AnyAtomic, StateClass::ExclusiveLike, Level::L3, LocalityClass::Local, 3.2)
        .rule(OpMatch::AnyAtomic, StateClass::ExclusiveLike, Level::L1, LocalityClass::Remote, 3.0)
        .rule(OpMatch::AnyAtomic, StateClass::ExclusiveLike, Level::L2, LocalityClass::Remote, 4.5)
        .rule(OpMatch::AnyAtomic, StateClass::ExclusiveLike, Level::L3, LocalityClass::Remote, 4.5)
        .rule(OpMatch::AnyAtomic, StateClass::SharedLike, Level::L1, LocalityClass::Local, 2.5)
        .rule(OpMatch::AnyAtomic, StateClass::SharedLike, Level::L2, LocalityClass::Local, 1.2)
        .rule(OpMatch::AnyAtomic, StateClass::SharedLike, Level::L3, LocalityClass::Local, -3.5)
        .rule(OpMatch::AnyAtomic, StateClass::SharedLike, Level::L1, LocalityClass::Remote, -13.0)
        .rule(OpMatch::AnyAtomic, StateClass::SharedLike, Level::L2, LocalityClass::Remote, -12.0)
        .rule(OpMatch::AnyAtomic, StateClass::SharedLike, Level::L3, LocalityClass::Remote, -10.0)
        // §5.1.1: the Ivy Bridge L1 detects that a (failing) CAS will not
        // modify the line and serves it 2–3 ns faster than FAA/SWP in E/M.
        .rule(OpMatch::Only(OpKind::Cas), StateClass::ExclusiveLike, Level::L1, LocalityClass::Local, -2.5);

    MachineConfig {
        name: "Ivy Bridge",
        cpu_model: "Xeon E5-2697v2",
        // 24 cores: two 12-core sockets (each socket is one die/L3 domain).
        topology: Topology::new(24, 1, 12, 1),
        l1: CacheGeom { size: 32 * 1024, ways: 8, write_policy: WritePolicy::WriteBack },
        l2: CacheGeom { size: 256 * 1024, ways: 8, write_policy: WritePolicy::WriteBack },
        l3: Some(CacheGeom { size: 30 << 20, ways: 20, write_policy: WritePolicy::WriteBack }),
        l3_policy: L3Policy::InclusiveCoreValid,
        protocol: ProtocolKind::Mesif,
        // Table 2, Ivy Bridge column.
        timing: Timing {
            r_l1: 1.8,
            r_l2: 3.7,
            r_l3: 14.5,
            hop: 66.0, // QPI
            mem: 80.0,
            e_cas: 4.8,
            e_faa: 5.9,
            e_swp: 5.9,
            write_issue: 0.6,
        },
        overheads,
        write_buffer: WriteBufferCfg { entries: 36, merging: true, fastlock: false },
        mechanisms: Mechanisms::ALL_OFF,
        ht_assist: None,
        muw: false,
        contended_write_combining: true, // §5.4: ~100 GB/s contended writes
        // Fitted by `repro calibrate --arch ivybridge` against the Fig. 8
        // plateau targets (data::fig8_targets); see EXPERIMENTS.md.
        handoff_overlap: 0.64,
        // Scalar hand-off pricing by default; `--topology routed` opts
        // into the two-ring + QPI fabric (sim::fabric).
        fabric: Fabric::Scalar,
        cas128_penalty: (0.0, 0.0),
        unaligned: UnalignedCfg { bus_lock_ns: 520.0 },
        frequency_mhz: 2700,
        interconnect: "2x QPI (8.0 GT/s)",
        memory: "64GB",
    }
}
