//! Intel Xeon Phi (MIC) testbed: Xeon Phi 7120, 61 cores on a ring.
//!
//! Private L1 (32 KB) and inclusive L2 (512 KB); no L3. MESI extended with
//! the GOLS directory states (Globally Owned, Locally Shared) to emulate
//! dirty sharing. Remote accesses pay the ring hop + distributed tag
//! directory lookup — the dominant H = 161.2 ns of Table 2. Uniquely among
//! the testbeds, CAS is measurably slower than FAA here (E(CAS) = 12.4 vs
//! E(FAA) = 2.4 ns, §5.1.3).

use crate::atomics::OpKind;
use crate::sim::config::*;
use crate::sim::fabric::Fabric;
use crate::sim::mechanisms::Mechanisms;
use crate::sim::protocol::ProtocolKind;
use crate::sim::timing::{Level, LocalityClass, OpMatch, OverheadTable, Timing};
use crate::sim::topology::Topology;
use crate::sim::writebuffer::WriteBufferCfg;

pub fn xeonphi() -> MachineConfig {
    let overheads = OverheadTable::new()
        // §5.1.3: FAA is ≈2 ns over read locally, ≈5 ns remotely; CAS adds
        // ≈10/15 ns on top (already mostly in E(CAS)); encode the remote
        // directory-check surcharges.
        .rule_any(OpMatch::AnyAtomic, None, Some(Level::L1), Some(LocalityClass::Remote), 3.0)
        .rule_any(OpMatch::Only(OpKind::Cas), None, Some(Level::L1), Some(LocalityClass::Remote), 5.0)
        .rule_any(OpMatch::Only(OpKind::Cas), None, Some(Level::L2), Some(LocalityClass::Remote), 5.0);

    MachineConfig {
        name: "Xeon Phi",
        cpu_model: "Xeon Phi 7120",
        // 61 cores, private L2, one ring domain (no L3, single "die").
        topology: Topology::new(61, 1, 61, 1),
        l1: CacheGeom { size: 32 * 1024, ways: 8, write_policy: WritePolicy::WriteBack },
        // L2 is inclusive of L1 on Phi (Table 1).
        l2: CacheGeom { size: 512 * 1024, ways: 8, write_policy: WritePolicy::WriteBack },
        l3: None,
        l3_policy: L3Policy::NonInclusive, // no L3; field unused
        protocol: ProtocolKind::MesiGols,
        // Table 2, Xeon Phi column.
        timing: Timing {
            r_l1: 2.4,
            r_l2: 19.4,
            r_l3: f64::NAN,
            hop: 161.2, // ring + distributed tag-directory lookup
            mem: 340.0,
            e_cas: 12.4,
            e_faa: 2.4,
            e_swp: 3.1,
            write_issue: 1.6, // in-order cores: costlier store issue
        },
        overheads,
        write_buffer: WriteBufferCfg { entries: 16, merging: true, fastlock: false },
        mechanisms: Mechanisms::ALL_OFF,
        ht_assist: None,
        muw: false,
        contended_write_combining: false, // §5.4: bandwidth collapses
        // Fitted by `repro calibrate --arch xeonphi` against the Fig. 8
        // plateau targets (data::fig8_targets); see EXPERIMENTS.md. The
        // highest of the four: with 61 requesters queued on the ring the
        // directory pipelines hand-offs almost completely, which is how
        // the Phi sustains its comparatively high contended-FAA plateau
        // despite the 197.6 ns cache-to-cache transfer.
        handoff_overlap: 0.95,
        // Scalar hand-off pricing by default — the scalar plateau is
        // capped at the uncontended rate, so Fig. 8c's ~3 GB/s raw
        // plateau needs `--topology routed`: the 61-stop directory ring
        // (sim::fabric) pipelines in-flight FAA hand-offs.
        fabric: Fabric::Scalar,
        cas128_penalty: (0.0, 0.0),
        unaligned: UnalignedCfg { bus_lock_ns: 900.0 },
        frequency_mhz: 1238,
        interconnect: "ring bus",
        memory: "8GB",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_l3() {
        assert!(xeonphi().l3.is_none());
        assert!(xeonphi().timing.r_l3.is_nan());
    }

    #[test]
    fn cas_slower_than_faa() {
        let t = xeonphi().timing;
        assert!(t.e_cas > t.e_faa, "§5.1.3: CAS slower than FAA on Phi");
    }

    #[test]
    fn ring_hop_dominates() {
        let t = xeonphi().timing;
        assert!(t.hop > 8.0 * t.r_l2);
    }
}
