//! Intel Haswell testbed: Core i7-4770, 4 cores, 1 CPU (Fig. 1a).
//!
//! Private L1 (32 KB) and L2 (256 KB), shared inclusive L3 (8 MB) with
//! core-valid bits, MESIF. The commodity multicore baseline of the paper.

use crate::atomics::OpKind;
use crate::sim::config::*;
use crate::sim::fabric::Fabric;
use crate::sim::mechanisms::Mechanisms;
use crate::sim::protocol::ProtocolKind;
use crate::sim::timing::{Level, LocalityClass, OpMatch, OverheadTable, StateClass, Timing};
use crate::sim::topology::Topology;
use crate::sim::writebuffer::WriteBufferCfg;

pub fn haswell() -> MachineConfig {
    // Table 3: the O residual for Haswell (ns).
    //               op                 state                      level      locality                ns
    let overheads = OverheadTable::new()
        .rule(OpMatch::AnyAtomic, StateClass::ExclusiveLike, Level::L2, LocalityClass::Local, 3.8)
        .rule(OpMatch::AnyAtomic, StateClass::ExclusiveLike, Level::L3, LocalityClass::Local, 3.5)
        .rule(OpMatch::AnyAtomic, StateClass::ExclusiveLike, Level::L1, LocalityClass::Remote, 3.0)
        .rule(OpMatch::AnyAtomic, StateClass::ExclusiveLike, Level::L2, LocalityClass::Remote, 5.0)
        .rule(OpMatch::AnyAtomic, StateClass::ExclusiveLike, Level::L3, LocalityClass::Remote, 5.0)
        .rule(OpMatch::AnyAtomic, StateClass::SharedLike, Level::L1, LocalityClass::Local, 3.0)
        .rule(OpMatch::AnyAtomic, StateClass::SharedLike, Level::L2, LocalityClass::Local, 1.4)
        .rule(OpMatch::AnyAtomic, StateClass::SharedLike, Level::L3, LocalityClass::Local, -4.0)
        .rule(OpMatch::AnyAtomic, StateClass::SharedLike, Level::L1, LocalityClass::Remote, -15.0)
        .rule(OpMatch::AnyAtomic, StateClass::SharedLike, Level::L2, LocalityClass::Remote, -14.0)
        .rule(OpMatch::AnyAtomic, StateClass::SharedLike, Level::L3, LocalityClass::Remote, -12.0)
        // §5.1.1: on Haswell L1, CAS is marginally faster than FAA/SWP.
        .rule(OpMatch::Only(OpKind::Cas), StateClass::ExclusiveLike, Level::L1, LocalityClass::Local, -0.5);

    MachineConfig {
        name: "Haswell",
        cpu_model: "Core i7-4770",
        topology: Topology::new(4, 1, 4, 1),
        l1: CacheGeom { size: 32 * 1024, ways: 8, write_policy: WritePolicy::WriteBack },
        l2: CacheGeom { size: 256 * 1024, ways: 8, write_policy: WritePolicy::WriteBack },
        l3: Some(CacheGeom { size: 8 << 20, ways: 16, write_policy: WritePolicy::WriteBack }),
        l3_policy: L3Policy::InclusiveCoreValid,
        protocol: ProtocolKind::Mesif,
        // Table 2, Haswell column.
        timing: Timing {
            r_l1: 1.17,
            r_l2: 3.5,
            r_l3: 10.3,
            hop: f64::NAN, // single socket — no interconnect
            mem: 65.0,
            e_cas: 4.7,
            e_faa: 5.6,
            e_swp: 5.6,
            write_issue: 0.5,
        },
        overheads,
        write_buffer: WriteBufferCfg { entries: 42, merging: true, fastlock: false },
        mechanisms: Mechanisms::ALL_OFF, // §3.3: everything disabled
        ht_assist: None,
        muw: false,
        contended_write_combining: true, // §5.4
        // Fitted by `repro calibrate --arch haswell` against the Fig. 8
        // plateau targets (data::fig8_targets); see EXPERIMENTS.md.
        handoff_overlap: 0.70,
        // Scalar hand-off pricing by default; `--topology routed` opts
        // into the ring-bus fabric (sim::fabric).
        fabric: Fabric::Scalar,
        cas128_penalty: (0.0, 0.0),      // §5.3: identical on Intel
        unaligned: UnalignedCfg { bus_lock_ns: 480.0 }, // §5.7: CAS up to ≈750ns
        frequency_mhz: 3400,
        interconnect: "-",
        memory: "8GB",
    }
}
