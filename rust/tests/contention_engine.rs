//! Integration tests for the machine-accurate multi-core contention
//! engine (Fig. 8, §5.4): cross-validation against the analytic event
//! model on all four architectures, uncontended-limit agreement with the
//! latency bench, determinism, and clamping.

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::contention::{
    paper_thread_counts, run_model, thread_sweep, ContentionModel, OPS_PER_THREAD,
};
use atomics_repro::bench::latency::LatencyBench;
use atomics_repro::bench::placement::{PrepLocality, PrepState};
use atomics_repro::sim::Machine;

const MODELS: [ContentionModel; 2] =
    [ContentionModel::MachineAccurate, ContentionModel::Analytic];

/// The acceptance criterion: the analytic and machine-accurate curves
/// agree in shape on all four architectures — contended atomics lose
/// bandwidth from 1 thread to the contended regime. Exception, faithful
/// to the paper's Fig. 8c: Xeon Phi CAS starts so slow (E(CAS) = 12.4 ns)
/// that its curve is flat-low rather than declining, so for (Phi, CAS)
/// both models must instead agree on the collapsed plateau (< 1.5 GB/s).
#[test]
fn models_agree_atomic_bandwidth_declines_on_all_arches() {
    for cfg in arch::all() {
        let n = cfg.topology.n_cores.min(8);
        let mut m = Machine::new(cfg);
        for op in [OpKind::Cas, OpKind::Faa] {
            for model in MODELS {
                let one = run_model(&mut m, model, 1, op, 800);
                let many = run_model(&mut m, model, n, op, 800);
                if m.cfg.name == "Xeon Phi" && op == OpKind::Cas {
                    assert!(
                        many.bandwidth_gbs < 1.5,
                        "Phi CAS {}: contended plateau must stay collapsed, got {}",
                        model.label(),
                        many.bandwidth_gbs
                    );
                    continue;
                }
                assert!(
                    one.bandwidth_gbs > many.bandwidth_gbs,
                    "{} {:?} {}: 1-thread {} must beat {n}-thread {}",
                    m.cfg.name,
                    op,
                    model.label(),
                    one.bandwidth_gbs,
                    many.bandwidth_gbs
                );
            }
        }
    }
}

/// §5.4's other headline, in both models: contended plain stores on the
/// Intel parts are absorbed by write combining and *scale*.
#[test]
fn models_agree_intel_write_combining_scales() {
    let mut m = Machine::new(arch::ivybridge());
    for model in MODELS {
        let one = run_model(&mut m, model, 1, OpKind::Write, 800);
        let eight = run_model(&mut m, model, 8, OpKind::Write, 800);
        assert!(
            eight.bandwidth_gbs > 3.0 * one.bandwidth_gbs,
            "{}: {} vs {}",
            model.label(),
            eight.bandwidth_gbs,
            one.bandwidth_gbs
        );
    }
}

/// Xeon Phi has no write combining: both models keep contended writes far
/// below the Intel parts' ~100 GB/s, and the machine-accurate schedule
/// (which serializes the stores on line ownership) shows the collapse.
#[test]
fn phi_contended_writes_stay_collapsed() {
    let mut m = Machine::new(arch::xeonphi());
    for model in MODELS {
        let r = run_model(&mut m, model, 16, OpKind::Write, 500);
        assert!(r.bandwidth_gbs < 20.0, "{}: {}", model.label(), r.bandwidth_gbs);
    }
    let one = run_model(&mut m, ContentionModel::MachineAccurate, 1, OpKind::Write, 500);
    let sixteen = run_model(&mut m, ContentionModel::MachineAccurate, 16, OpKind::Write, 500);
    assert!(
        sixteen.bandwidth_gbs < one.bandwidth_gbs,
        "{} vs {}",
        sixteen.bandwidth_gbs,
        one.bandwidth_gbs
    );
}

/// The machine-accurate 1-thread limit must agree with the uncontended
/// latency pointer-chase (same engine, same fast path) within tolerance —
/// only the cold-miss transient differs.
#[test]
fn one_thread_matches_uncontended_latency_bench() {
    for cfg in arch::all() {
        let mut m = Machine::new(cfg);
        for op in [OpKind::Faa, OpKind::Cas] {
            let contended =
                run_model(&mut m, ContentionModel::MachineAccurate, 1, op, OPS_PER_THREAD);
            let uncontended = LatencyBench::new(op, PrepState::M, PrepLocality::Local)
                .run_once(&m.cfg, 4096)
                .unwrap();
            let rel = (contended.mean_latency_ns - uncontended).abs() / uncontended;
            assert!(
                rel < 0.25,
                "{} {:?}: contended(1) {} vs uncontended {} ({}% off)",
                m.cfg.name,
                op,
                contended.mean_latency_ns,
                uncontended,
                rel * 100.0
            );
        }
    }
}

/// CAS failures are emergent: zero without rivals, growing with them.
#[test]
fn cas_failure_rate_diverges_with_thread_count() {
    let mut m = Machine::new(arch::ivybridge());
    let r1 = run_model(&mut m, ContentionModel::MachineAccurate, 1, OpKind::Cas, 500);
    let r2 = run_model(&mut m, ContentionModel::MachineAccurate, 2, OpKind::Cas, 500);
    let r8 = run_model(&mut m, ContentionModel::MachineAccurate, 8, OpKind::Cas, 500);
    assert_eq!(r1.cas_failure_rate(), 0.0);
    assert!(r2.cas_failure_rate() > 0.0);
    assert!(
        r8.cas_failure_rate() > r2.cas_failure_rate(),
        "{} vs {}",
        r8.cas_failure_rate(),
        r2.cas_failure_rate()
    );
    // FAA never fails — its consensus number is paid in other coin (§2.3)
    let faa = run_model(&mut m, ContentionModel::MachineAccurate, 8, OpKind::Faa, 500);
    assert_eq!(faa.cas_failure_rate(), 0.0);
}

/// `thread_sweep` clamps to the core count and is bit-deterministic
/// across repeated runs, per-thread stats included.
#[test]
fn thread_sweep_clamps_and_is_deterministic() {
    let cfg = arch::haswell(); // 4 cores
    for model in MODELS {
        assert_eq!(thread_sweep(&cfg, OpKind::Faa, 1000, model).len(), 4);
    }

    let cfg = arch::ivybridge();
    let a = thread_sweep(&cfg, OpKind::Cas, 6, ContentionModel::MachineAccurate);
    let b = thread_sweep(&cfg, OpKind::Cas, 6, ContentionModel::MachineAccurate);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.bandwidth_gbs.to_bits(), y.bandwidth_gbs.to_bits(), "{} threads", x.threads);
        assert_eq!(x.mean_latency_ns.to_bits(), y.mean_latency_ns.to_bits());
        assert_eq!(x.per_thread, y.per_thread);
    }
}

/// The analytic model reads only the configuration: running it on a
/// machine dirtied by a prior machine-accurate run changes nothing (the
/// `needs_machine() == false` contract the sweep executor relies on).
#[test]
fn analytic_model_ignores_machine_state() {
    let cfg = arch::bulldozer();
    let mut fresh = Machine::new(cfg.clone());
    let baseline = run_model(&mut fresh, ContentionModel::Analytic, 8, OpKind::Faa, 400);

    let mut dirty = Machine::new(cfg);
    run_model(&mut dirty, ContentionModel::MachineAccurate, 16, OpKind::Cas, 200);
    let after = run_model(&mut dirty, ContentionModel::Analytic, 8, OpKind::Faa, 400);
    assert_eq!(baseline.bandwidth_gbs.to_bits(), after.bandwidth_gbs.to_bits());
}

/// Every thread completes its quota and the stats account for the run:
/// contended threads all see migrations and arbitration stalls.
#[test]
fn per_thread_stats_account_for_the_run() {
    let mut m = Machine::new(arch::bulldozer());
    let r = run_model(&mut m, ContentionModel::MachineAccurate, 16, OpKind::Cas, 300);
    assert_eq!(r.per_thread.len(), 16);
    for st in &r.per_thread {
        assert_eq!(st.ops, 300, "thread {} lost ops", st.core);
        assert!(st.line_hops > 0, "thread {} saw no ping-pong", st.core);
        assert!(st.stall_ns > 0.0, "thread {} never stalled", st.core);
        assert!(st.mean_latency_ns() > 0.0);
    }
    assert!(r.total_line_hops() > r.total_ops() / 2);
    m.check_invariants().unwrap();
}

/// The routed-fabric acceptance gate: after calibrating the Phi ring's
/// injection leg against the paper's *raw* Fig. 8c plateau (~3 GB/s —
/// above the Phi's own uncontended FAA rate, so provably out of reach
/// for the scalar hand-off model), the contended-FAA plateau lands
/// within 30% of the target. The scalar path's plateau stays pinned by
/// the tests above and `tests/fit_native.rs` is untouched — the fabric
/// fit is a separate knob (`RoutedFabric::inject_ns`), not a
/// recalibration of `handoff_overlap`.
#[test]
fn calibrated_fabric_reproduces_the_phi_raw_faa_plateau() {
    use atomics_repro::data::fig8_targets::fabric_targets_for;
    use atomics_repro::fit::calibrate::{calibrate_fabric, FabricCalibrationCfg};

    let cfg = arch::xeonphi();
    let targets = fabric_targets_for(cfg.name);
    assert_eq!(targets.len(), 1, "Phi fabric targets are FAA-only");
    // The scalar model's contended plateau is capped near
    // 8 / (E(FAA) + (1−overlap)·T(same die)) ≈ 0.65 GB/s on the Phi —
    // the raw target must sit above it or the fabric adds nothing.
    let scalar_cap = 8.0
        / (cfg.timing.e_faa + (1.0 - cfg.handoff_overlap) * cfg.timing.same_die_transfer());
    assert!(
        targets[0].gbs > 2.0 * scalar_cap,
        "raw plateau {} vs scalar cap {scalar_cap}",
        targets[0].gbs
    );

    let ccfg = FabricCalibrationCfg {
        ops_per_thread: 200,
        coarse: 9,
        refine: 12,
        run_threads: 1,
        ..FabricCalibrationCfg::default()
    };
    let r = calibrate_fabric(&cfg, &targets, &ccfg).expect("Phi has fabric targets");
    assert_eq!(r.topology, "phi-ring");
    assert!(
        r.mean_rel_residual < 0.30,
        "calibrated Phi FAA plateau off by {:.0}% (fitted inject {} ns)",
        r.mean_rel_residual * 100.0,
        r.fitted_inject_ns
    );
    for p in &r.points {
        assert!(
            p.rel_residual() < 0.30,
            "{:?} @{}: achieved {} vs target {}",
            p.op,
            p.threads,
            p.achieved_gbs,
            p.target_gbs
        );
    }
}

/// Thread counts derive from the topology: 1, powers of two, full count.
#[test]
fn paper_thread_counts_cover_the_topology() {
    for cfg in arch::all() {
        let counts = paper_thread_counts(&cfg);
        assert_eq!(counts[0], 1);
        assert_eq!(*counts.last().unwrap(), cfg.topology.n_cores);
        assert!(counts.windows(2).all(|w| w[0] < w[1]), "{counts:?} not increasing");
    }
}
