//! Run-level parallelism golden tests: every ladder that now runs on a
//! [`RunPool`] must be *bit-identical* to the retained serial path for
//! any worker count — parallelism is a wall-clock optimization, never a
//! semantic one. Also pins arena reuse (a worker's [`RunArena`] carried
//! across runs) against fresh-arena runs, and the `--pin-workers` no-op
//! contract off Linux.

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::contention::{
    run_model, run_model_in, ContentionModel, ContentionPoint,
};
use atomics_repro::bench::locks::{run_lock, run_lock_in, LockKind, LockResult};
use atomics_repro::data::fig8_targets::targets_for;
use atomics_repro::fit::calibrate::{calibrate, CalibrationCfg};
use atomics_repro::sim::{Machine, RunArena};
use atomics_repro::sweep::RunPool;
use atomics_repro::util::affinity;

/// Small-but-contended op count: large enough to exercise hand-offs,
/// serialization slots, and CAS failures on every topology, small enough
/// that the full matrix (4 arches × 3 pool widths × ladders) stays fast.
const OPS: usize = 150;

fn assert_point_bits_eq(a: &ContentionPoint, b: &ContentionPoint, ctx: &str) {
    assert_eq!(a.threads, b.threads, "{ctx}: threads");
    assert_eq!(a.op, b.op, "{ctx}: op");
    assert_eq!(
        a.bandwidth_gbs.to_bits(),
        b.bandwidth_gbs.to_bits(),
        "{ctx}: bandwidth {} vs {}",
        a.bandwidth_gbs,
        b.bandwidth_gbs
    );
    assert_eq!(
        a.mean_latency_ns.to_bits(),
        b.mean_latency_ns.to_bits(),
        "{ctx}: mean latency"
    );
    assert_eq!(a.elapsed_ns.to_bits(), b.elapsed_ns.to_bits(), "{ctx}: elapsed");
    assert_eq!(a.per_thread, b.per_thread, "{ctx}: per-thread stats");
}

fn assert_lock_bits_eq(a: &LockResult, b: &LockResult, ctx: &str) {
    assert_eq!(a.kind, b.kind, "{ctx}: kind");
    assert_eq!(a.threads, b.threads, "{ctx}: threads");
    assert_eq!(a.acquisitions, b.acquisitions, "{ctx}: acquisitions");
    assert_eq!(a.attempts, b.attempts, "{ctx}: attempts");
    assert_eq!(a.failed_attempts, b.failed_attempts, "{ctx}: failed attempts");
    assert_eq!(a.spin_reads, b.spin_reads, "{ctx}: spin reads");
    assert_eq!(a.elapsed_ns.to_bits(), b.elapsed_ns.to_bits(), "{ctx}: elapsed");
    assert_eq!(a.acq_per_sec.to_bits(), b.acq_per_sec.to_bits(), "{ctx}: acq/s");
    assert_eq!(a.per_thread, b.per_thread, "{ctx}: per-thread stats");
}

/// Contend ladders (the `repro contend` / Fig. 8 unit): the machine-
/// accurate runs from a RunPool of 1, 2, and 4 workers are bit-identical
/// to a plain serial loop over one reused machine, on all four arches.
#[test]
fn contend_bit_identical_across_pool_widths() {
    for cfg in arch::all() {
        let counts = atomics_repro::bench::contention::paper_thread_counts(&cfg);
        let items: Vec<(OpKind, usize)> = [OpKind::Cas, OpKind::Faa, OpKind::Write]
            .into_iter()
            .flat_map(|op| counts.iter().map(move |&n| (op, n)))
            .collect();

        // retained serial path: one machine, fresh arena per run
        let mut m = Machine::new(cfg.clone());
        let serial: Vec<ContentionPoint> = items
            .iter()
            .map(|&(op, n)| run_model(&mut m, ContentionModel::MachineAccurate, n, op, OPS))
            .collect();

        for workers in [1usize, 2, 4] {
            let got = RunPool::new(workers).map(
                &items,
                || (Machine::new(cfg.clone()), RunArena::new()),
                |(m, arena), &(op, n)| {
                    run_model_in(m, arena, ContentionModel::MachineAccurate, n, op, OPS)
                },
            );
            assert_eq!(got.len(), serial.len());
            for (i, (s, p)) in serial.iter().zip(&got).enumerate() {
                let (op, n) = items[i];
                assert_point_bits_eq(
                    s,
                    p,
                    &format!("{} {:?} threads={n} workers={workers}", cfg.name, op),
                );
            }
        }
    }
}

/// Lock/queue ladders (§6.1): same contract, over every lock kind. Kinds
/// below their minimum thread count return None identically on both
/// paths.
#[test]
fn locks_bit_identical_across_pool_widths() {
    for cfg in arch::all() {
        let counts = [1usize, 2, 4];
        let items: Vec<(LockKind, usize)> = LockKind::ALL
            .iter()
            .flat_map(|&k| counts.iter().map(move |&n| (k, n)))
            .collect();

        let mut m = Machine::new(cfg.clone());
        let serial: Vec<Option<LockResult>> =
            items.iter().map(|&(k, n)| run_lock(&mut m, k, n, 30)).collect();

        for workers in [1usize, 2, 4] {
            let got = RunPool::new(workers).map(
                &items,
                || (Machine::new(cfg.clone()), RunArena::new()),
                |(m, arena), &(k, n)| run_lock_in(m, arena, k, n, 30),
            );
            for (i, (s, p)) in serial.iter().zip(&got).enumerate() {
                let (k, n) = items[i];
                let ctx = format!("{} {} threads={n} workers={workers}", cfg.name, k.label());
                match (s, p) {
                    (None, None) => {}
                    (Some(a), Some(b)) => assert_lock_bits_eq(a, b, &ctx),
                    _ => panic!("{ctx}: Some/None mismatch"),
                }
            }
        }
    }
}

/// The calibrator (coarse grid + reporting pass on the pool): fitted
/// overlap, residual, evaluation count, and every reported point are
/// bit-identical across run-thread counts on all four arches.
#[test]
fn calibrate_bit_identical_across_run_threads() {
    for cfg in arch::all() {
        let targets = targets_for(cfg.name);
        assert!(!targets.is_empty(), "{}: no Fig. 8 targets", cfg.name);
        let ccfg = |run_threads: usize| CalibrationCfg {
            ops_per_thread: 120,
            lo: 0.05,
            hi: 0.95,
            coarse: 5,
            refine: 5,
            run_threads,
            ..CalibrationCfg::default()
        };
        let base = calibrate(&cfg, &targets, &ccfg(1)).unwrap();
        for workers in [2usize, 4] {
            let r = calibrate(&cfg, &targets, &ccfg(workers)).unwrap();
            assert_eq!(
                base.fitted_overlap.to_bits(),
                r.fitted_overlap.to_bits(),
                "{} workers={workers}: fitted overlap {} vs {}",
                cfg.name,
                base.fitted_overlap,
                r.fitted_overlap
            );
            assert_eq!(
                base.mean_rel_residual.to_bits(),
                r.mean_rel_residual.to_bits(),
                "{} workers={workers}: residual",
                cfg.name
            );
            assert_eq!(base.evaluations, r.evaluations, "{}: evaluations", cfg.name);
            assert_eq!(base.points.len(), r.points.len());
            for (a, b) in base.points.iter().zip(&r.points) {
                assert_eq!(a.op, b.op);
                assert_eq!(a.threads, b.threads);
                assert_eq!(a.target_gbs.to_bits(), b.target_gbs.to_bits());
                assert_eq!(
                    a.achieved_gbs.to_bits(),
                    b.achieved_gbs.to_bits(),
                    "{} workers={workers}: achieved at {:?}x{}",
                    cfg.name,
                    a.op,
                    a.threads
                );
                assert_eq!(a.from_paper, b.from_paper);
            }
        }
    }
}

/// Arena reuse is unobservable: a single arena carried across a mixed
/// run sequence (different thread counts, ops, lock kinds — each run
/// larger and smaller than the last, so both grow and shrink paths hit)
/// produces bit-identical results to a fresh arena per run.
#[test]
fn arena_reuse_bit_identical_to_fresh() {
    for cfg in [arch::haswell(), arch::bulldozer()] {
        let mut m = Machine::new(cfg.clone());
        let mut arena = RunArena::new();
        let seq = [
            (OpKind::Cas, 4usize),
            (OpKind::Faa, 2),
            (OpKind::Write, 4),
            (OpKind::Cas, 1),
            (OpKind::Cas, 4),
        ];
        for &(op, n) in &seq {
            let reused =
                run_model_in(&mut m, &mut arena, ContentionModel::MachineAccurate, n, op, OPS);
            let fresh = run_model_in(
                &mut m,
                &mut RunArena::new(),
                ContentionModel::MachineAccurate,
                n,
                op,
                OPS,
            );
            assert_point_bits_eq(
                &reused,
                &fresh,
                &format!("{} {:?} threads={n} (reused arena)", cfg.name, op),
            );
        }
        // and across engines: the program scheduler shares the same arena
        for &(k, n) in &[(LockKind::TasSpin, 4usize), (LockKind::Mpsc, 2), (LockKind::Ticket, 4)]
        {
            let reused = run_lock_in(&mut m, &mut arena, k, n, 25);
            let fresh = run_lock_in(&mut m, &mut RunArena::new(), k, n, 25);
            match (&reused, &fresh) {
                (Some(a), Some(b)) => assert_lock_bits_eq(
                    a,
                    b,
                    &format!("{} {} threads={n} (reused arena)", cfg.name, k.label()),
                ),
                (None, None) => {}
                _ => panic!("{} {}: Some/None mismatch", cfg.name, k.label()),
            }
        }
    }
}

/// A full report through the pool: `locks_report_with` renders the same
/// bytes at 1 and 4 workers (tables, stats tables, elision lines — the
/// whole §6.1 text).
#[test]
fn locks_report_renders_identical_bytes_across_pool_widths() {
    let cfg = arch::haswell();
    let kinds = LockKind::ALL.to_vec();
    let counts = [1usize, 2, 4];
    let one = atomics_repro::report::figures::locks_report_with(
        &RunPool::new(1),
        &cfg,
        &kinds,
        &counts,
        25,
        true,
    );
    let four = atomics_repro::report::figures::locks_report_with(
        &RunPool::new(4),
        &cfg,
        &kinds,
        &counts,
        25,
        true,
    );
    assert!(!one.is_empty());
    assert_eq!(one, four, "locks report must not depend on pool width");
}

/// `repro bfs` (the last PR 6 residual): its per-mode BFS simulations
/// are run-pool work items, each on a fresh machine (`parallel_bfs` has
/// no fresh-machine reset, so machines must not be pooled across
/// items). Parent trees, MTEPS bits, and claim counters are identical
/// to the serial fresh-machine path at widths 1, 2, and 4.
#[test]
fn bfs_bit_identical_across_pool_widths() {
    use atomics_repro::graph::bfs::validate_tree;
    use atomics_repro::graph::{kronecker_edges, parallel_bfs, BfsMode, Csr};

    let cfg = arch::haswell();
    let scale = 8u32;
    let csr = Csr::from_edges(1 << scale, &kronecker_edges(scale, 0xBF5));
    let root = csr.first_non_isolated().unwrap();
    let modes = [BfsMode::Cas, BfsMode::Swp];

    let serial: Vec<_> = modes
        .iter()
        .map(|&mode| parallel_bfs(&mut Machine::new(cfg.clone()), &csr, root, 4, mode))
        .collect();
    for (r, mode) in serial.iter().zip(&modes) {
        validate_tree(&csr, root, &r.parent)
            .unwrap_or_else(|e| panic!("{}: invalid tree: {e}", mode.label()));
    }

    for workers in [1usize, 2, 4] {
        let got = RunPool::new(workers).map(
            &modes,
            || (),
            |(), &mode| parallel_bfs(&mut Machine::new(cfg.clone()), &csr, root, 4, mode),
        );
        for ((s, p), mode) in serial.iter().zip(&got).zip(&modes) {
            let ctx = format!("{} workers={workers}", mode.label());
            assert_eq!(s.parent, p.parent, "{ctx}: parent tree");
            assert_eq!(s.mteps.to_bits(), p.mteps.to_bits(), "{ctx}: MTEPS");
            assert_eq!(s.elapsed_ns.to_bits(), p.elapsed_ns.to_bits(), "{ctx}: elapsed");
            assert_eq!(s.edges_scanned, p.edges_scanned, "{ctx}: edges scanned");
            assert_eq!(s.wasted_claims, p.wasted_claims, "{ctx}: wasted claims");
        }
    }
}

/// Steady-state fast-forward goldens (`--steady-state`): `on` is
/// bit-identical to the retained stepwise `off` path for contend ladders
/// under both the scalar and the routed fabric, on all four arches, at
/// pool widths 1, 2, and 4. Like the pool itself, the detector is a
/// wall-clock optimization only — down to the per-link fabric counters.
#[test]
fn steady_contend_bit_identical_scalar_and_routed_across_pool_widths() {
    use atomics_repro::sim::fabric::Fabric;
    use atomics_repro::sim::multicore::run_contention_steady;
    use atomics_repro::sim::SteadyMode;

    const STEADY_OPS: usize = 400;
    for base in arch::all() {
        for use_routed in [false, true] {
            let mut cfg = base.clone();
            if use_routed {
                cfg.fabric = Fabric::routed_for(&cfg);
            }
            let fab = if use_routed { "routed" } else { "scalar" };
            let n = cfg.topology.n_cores.min(4);
            let items = [(OpKind::Cas, n), (OpKind::Faa, n), (OpKind::Write, n)];

            // Reference: the stepwise path, serial.
            let mut m = Machine::new(cfg.clone());
            let off: Vec<_> = items
                .iter()
                .map(|&(op, n)| {
                    run_contention_steady(
                        &mut m,
                        &mut RunArena::new(),
                        n,
                        op,
                        STEADY_OPS,
                        SteadyMode::Off,
                    )
                    .0
                })
                .collect();

            for workers in [1usize, 2, 4] {
                let on = RunPool::new(workers).map(
                    &items,
                    || (Machine::new(cfg.clone()), RunArena::new()),
                    |(m, arena), &(op, n)| {
                        run_contention_steady(m, arena, n, op, STEADY_OPS, SteadyMode::On)
                    },
                );
                for (i, (o, (p, info))) in off.iter().zip(&on).enumerate() {
                    let (op, n) = items[i];
                    let ctx =
                        format!("{} {fab} {:?} threads={n} workers={workers}", base.name, op);
                    assert!(!info.aborted, "{ctx}: replay aborted");
                    assert_eq!(
                        o.bandwidth_gbs.to_bits(),
                        p.bandwidth_gbs.to_bits(),
                        "{ctx}: bandwidth {} vs {}",
                        o.bandwidth_gbs,
                        p.bandwidth_gbs
                    );
                    assert_eq!(
                        o.mean_latency_ns.to_bits(),
                        p.mean_latency_ns.to_bits(),
                        "{ctx}: mean latency"
                    );
                    assert_eq!(o.elapsed_ns.to_bits(), p.elapsed_ns.to_bits(), "{ctx}: elapsed");
                    assert_eq!(o.per_thread, p.per_thread, "{ctx}: per-thread stats");
                    assert_eq!(o.links, p.links, "{ctx}: per-link fabric stats");
                }
            }
        }
    }
}

/// Steady-state goldens over the lock/queue family: `--steady-state on`
/// is bit-identical to `off` for every lock kind on every arch (kinds
/// below their minimum thread count return None identically).
#[test]
fn steady_locks_bit_identical_for_every_kind() {
    use atomics_repro::bench::locks::run_lock_in_steady;
    use atomics_repro::sim::{SteadyInfo, SteadyMode};

    for cfg in arch::all() {
        let mut m = Machine::new(cfg.clone());
        for &kind in LockKind::ALL.iter() {
            let off =
                run_lock_in_steady(&mut m, &mut RunArena::new(), kind, 4, 40, SteadyMode::Off);
            let on =
                run_lock_in_steady(&mut m, &mut RunArena::new(), kind, 4, 40, SteadyMode::On);
            let ctx = format!("{} {} steady", cfg.name, kind.label());
            match (off, on) {
                (None, None) => {}
                (Some((a, ai)), Some((b, bi))) => {
                    assert_eq!(ai, SteadyInfo::default(), "{ctx}: off must stay inert");
                    assert!(!bi.aborted, "{ctx}: replay aborted");
                    assert_lock_bits_eq(&a, &b, &ctx);
                }
                _ => panic!("{ctx}: Some/None mismatch"),
            }
        }
    }
}

/// `--pin-workers` smoke: results are bit-identical with pinning
/// requested, and on non-Linux platforms the pin itself reports `false`
/// (a documented no-op) while everything still runs.
#[test]
fn pin_workers_is_a_harmless_opt_in() {
    let cfg = arch::haswell();
    let counts = [1usize, 2, 4];
    let plain = RunPool::new(2).map(
        &counts,
        || (Machine::new(cfg.clone()), RunArena::new()),
        |(m, arena), &n| {
            run_model_in(m, arena, ContentionModel::MachineAccurate, n, OpKind::Faa, OPS)
        },
    );
    let pinned = RunPool::new(2).pinned(true).map(
        &counts,
        || (Machine::new(cfg.clone()), RunArena::new()),
        |(m, arena), &n| {
            run_model_in(m, arena, ContentionModel::MachineAccurate, n, OpKind::Faa, OPS)
        },
    );
    for (i, (a, b)) in plain.iter().zip(&pinned).enumerate() {
        assert_point_bits_eq(a, b, &format!("pinned vs plain, item {i}"));
    }
    let pin_took = std::thread::spawn(|| affinity::pin_current_thread(0)).join().unwrap();
    if !affinity::pinning_supported() {
        assert!(!pin_took, "pinning must be a no-op off Linux");
    }
}
