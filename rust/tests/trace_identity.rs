//! Observer-hook golden tests (DESIGN.md §13): attaching any
//! [`TraceSink`] to the multicore schedulers leaves every reported number
//! **bit-identical** — tracing is an observation, never a perturbation.
//! Also reconciles the emitted event stream against the schedulers' own
//! stats (each grant/hand-off/invalidation is seen exactly once), pins
//! the metrics registry's per-thread mirror against the scheduler's, and
//! structurally validates the Chrome trace-event JSON.

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::contention::{
    run_model_sink, run_model_steady_in, ContentionModel, ContentionPoint,
};
use atomics_repro::bench::locks::{run_lock_in_steady, run_lock_sink, LockKind, LockResult};
use atomics_repro::obs::{ChromeTrace, CollectSink, Metrics, Tee, TraceEvent};
use atomics_repro::sim::{Fabric, Machine, MachineConfig, RunArena, SteadyMode};
use atomics_repro::sweep::RunPool;

/// Contended-enough op count (hand-offs, CAS failures, steady periods on
/// every arch) that keeps the full matrix fast.
const OPS: usize = 150;

/// Each arch in both interconnect pricings: scalar hop model and the
/// routed link-level fabric.
fn variants() -> Vec<(String, MachineConfig)> {
    let mut v = Vec::new();
    for cfg in arch::all() {
        v.push((format!("{} scalar", cfg.name), cfg.clone()));
        let mut routed = cfg.clone();
        routed.fabric = Fabric::routed_for(&cfg);
        v.push((format!("{} routed", cfg.name), routed));
    }
    v
}

fn assert_point_bits_eq(a: &ContentionPoint, b: &ContentionPoint, ctx: &str) {
    assert_eq!(a.threads, b.threads, "{ctx}: threads");
    assert_eq!(a.op, b.op, "{ctx}: op");
    assert_eq!(a.bandwidth_gbs.to_bits(), b.bandwidth_gbs.to_bits(), "{ctx}: bandwidth");
    assert_eq!(a.mean_latency_ns.to_bits(), b.mean_latency_ns.to_bits(), "{ctx}: latency");
    assert_eq!(a.elapsed_ns.to_bits(), b.elapsed_ns.to_bits(), "{ctx}: elapsed");
    assert_eq!(a.per_thread, b.per_thread, "{ctx}: per-thread stats");
    assert_eq!(a.links, b.links, "{ctx}: link stats");
}

fn assert_lock_bits_eq(a: &LockResult, b: &LockResult, ctx: &str) {
    assert_eq!(a.kind, b.kind, "{ctx}: kind");
    assert_eq!(a.threads, b.threads, "{ctx}: threads");
    assert_eq!(a.acquisitions, b.acquisitions, "{ctx}: acquisitions");
    assert_eq!(a.attempts, b.attempts, "{ctx}: attempts");
    assert_eq!(a.failed_attempts, b.failed_attempts, "{ctx}: failed attempts");
    assert_eq!(a.spin_reads, b.spin_reads, "{ctx}: spin reads");
    assert_eq!(a.elapsed_ns.to_bits(), b.elapsed_ns.to_bits(), "{ctx}: elapsed");
    assert_eq!(a.acq_per_sec.to_bits(), b.acq_per_sec.to_bits(), "{ctx}: acq/s");
    assert_eq!(a.per_thread, b.per_thread, "{ctx}: per-thread stats");
}

/// Contend (Fig. 8 unit): tracing on vs off, on every arch × topology ×
/// op × steady mode. The traced run's events must also reconcile with the
/// scheduler's own sums — every grant, invalidation, interconnect hop,
/// CAS failure and (for serializing atomics) line hop is seen exactly
/// once — and the metrics registry's per-thread mirror must be the
/// scheduler's stats, bitwise.
#[test]
fn contend_trace_attached_is_bit_identical_and_reconciles() {
    for (name, cfg) in variants() {
        for op in [OpKind::Cas, OpKind::Faa] {
            for steady in [SteadyMode::Off, SteadyMode::On] {
                let threads = cfg.topology.n_cores.min(4);
                let ctx = format!("{name} {op:?} steady={steady:?}");

                let mut m = Machine::new(cfg.clone());
                let (plain, plain_info) = run_model_steady_in(
                    &mut m,
                    &mut RunArena::new(),
                    ContentionModel::MachineAccurate,
                    threads,
                    op,
                    OPS,
                    steady,
                );

                let mut sink = Tee(CollectSink::new(), Metrics::new());
                let mut m2 = Machine::new(cfg.clone());
                let (traced, traced_info) = run_model_sink(
                    &mut m2,
                    &mut RunArena::new(),
                    threads,
                    op,
                    OPS,
                    steady,
                    &mut sink,
                );
                assert_point_bits_eq(&plain, &traced, &ctx);
                assert_eq!(plain_info.engaged, traced_info.engaged, "{ctx}: engaged");
                assert_eq!(
                    plain_info.events_skipped, traced_info.events_skipped,
                    "{ctx}: events skipped"
                );

                let Tee(collect, metrics) = sink;
                // The registry's per-thread mirror IS the scheduler's.
                assert_eq!(metrics.per_thread(), &traced.per_thread[..], "{ctx}: mirror");

                // Event-count reconciliation against the result's sums.
                let mut grants = 0u64;
                let mut counted = 0u64;
                let mut inv = 0u64;
                let mut hops = 0u64;
                let mut cas_failed = 0u64;
                let mut handoffs = 0u64;
                for ev in &collect.events {
                    match *ev {
                        TraceEvent::Grant {
                            counted: c,
                            cas_failed: cf,
                            d_hops,
                            d_inv,
                            ..
                        } => {
                            grants += 1;
                            if c {
                                counted += 1;
                            }
                            inv += d_inv;
                            hops += d_hops;
                            if cf {
                                cas_failed += 1;
                            }
                        }
                        TraceEvent::Handoff { .. } => handoffs += 1,
                        _ => {}
                    }
                }
                let total_ops: u64 = traced.per_thread.iter().map(|t| t.ops).sum();
                assert_eq!(grants, total_ops, "{ctx}: one grant per op");
                assert_eq!(counted, total_ops, "{ctx}: all contend grants counted");
                assert_eq!(inv, traced.total_invalidations(), "{ctx}: invalidations");
                let total_hops: u64 =
                    traced.per_thread.iter().map(|t| t.interconnect_hops).sum();
                assert_eq!(hops, total_hops, "{ctx}: interconnect hops");
                let total_cas: u64 = traced.per_thread.iter().map(|t| t.cas_failures).sum();
                assert_eq!(cas_failed, total_cas, "{ctx}: CAS failures");
                // CAS/FAA serialize on every machine, so each line hop is
                // exactly one hand-off event.
                assert_eq!(handoffs, traced.total_line_hops(), "{ctx}: hand-offs");
                assert_eq!(metrics.grants(), grants, "{ctx}: metrics grants");
                assert_eq!(metrics.handoffs(), handoffs, "{ctx}: metrics hand-offs");
                if steady == SteadyMode::On && traced_info.engaged {
                    assert!(
                        metrics.steady_engaged(),
                        "{ctx}: steady engage transition observed"
                    );
                }
            }
        }
    }
}

/// The traced serial run against pooled untraced runs at widths 1/2/4:
/// observation composes with run-level parallelism without breaking the
/// pool's bit-identity contract.
#[test]
fn traced_serial_matches_pooled_untraced_at_every_width() {
    let cfg = arch::ivybridge();
    let counts = [1usize, 2, 4];
    let op = OpKind::Cas;

    let traced: Vec<ContentionPoint> = counts
        .iter()
        .map(|&n| {
            let mut sink = Tee(CollectSink::new(), Metrics::new());
            let mut m = Machine::new(cfg.clone());
            run_model_sink(&mut m, &mut RunArena::new(), n, op, OPS, SteadyMode::Off, &mut sink)
                .0
        })
        .collect();

    for workers in [1usize, 2, 4] {
        let pooled = RunPool::new(workers).map(
            &counts,
            || (Machine::new(cfg.clone()), RunArena::new()),
            |(m, arena), &n| {
                run_model_steady_in(
                    m,
                    arena,
                    ContentionModel::MachineAccurate,
                    n,
                    op,
                    OPS,
                    SteadyMode::Off,
                )
                .0
            },
        );
        for (t, p) in traced.iter().zip(&pooled) {
            assert_point_bits_eq(t, p, &format!("threads={} workers={workers}", t.threads));
        }
    }
}

/// Locks (§6.1): the program-path scheduler with a sink attached is
/// bit-identical for every lock kind, and the metrics mirror matches the
/// scheduler's per-thread stats.
#[test]
fn locks_trace_attached_is_bit_identical() {
    for cfg in [arch::haswell(), arch::ivybridge()] {
        for kind in LockKind::ALL {
            for steady in [SteadyMode::Off, SteadyMode::On] {
                let ctx = format!("{} {} steady={steady:?}", cfg.name, kind.label());
                let mut m = Machine::new(cfg.clone());
                let plain =
                    run_lock_in_steady(&mut m, &mut RunArena::new(), kind, 4, 40, steady);

                let mut sink = Tee(CollectSink::new(), Metrics::new());
                let mut m2 = Machine::new(cfg.clone());
                let traced = run_lock_sink(
                    &mut m2,
                    &mut RunArena::new(),
                    kind,
                    4,
                    40,
                    steady,
                    &mut sink,
                );
                match (plain, traced) {
                    (Some((a, _)), Some((b, _))) => {
                        assert_lock_bits_eq(&a, &b, &ctx);
                        let Tee(collect, metrics) = sink;
                        assert_eq!(metrics.per_thread(), &b.per_thread[..], "{ctx}: mirror");
                        assert!(!collect.events.is_empty(), "{ctx}: events flowed");
                        // Uncounted spin polls exist, so grants ≥ counted.
                        assert!(metrics.grants() >= metrics.counted_ops(), "{ctx}");
                    }
                    (None, None) => {}
                    _ => panic!("{ctx}: traced and untraced disagree on feasibility"),
                }
            }
        }
    }
}

/// Predict: the serving engine's results are bit-identical whether or not
/// harness profiling observes it, and LRU probes feed the global profile.
#[test]
fn predict_profiled_is_bit_identical_and_feeds_profile() {
    use atomics_repro::serve::{canonical_grid, ArchId, PredictEngine, PredictRequest};
    let cfg = arch::haswell();
    let reqs: Vec<PredictRequest> = canonical_grid(&cfg)
        .into_iter()
        .take(24)
        .map(|q| PredictRequest::new(ArchId::Haswell, q))
        .collect();

    let mut plain_engine = PredictEngine::shipped();
    let plain = plain_engine.predict_batch(&reqs).expect("valid grid batch");

    let before = atomics_repro::obs::profile::global().snapshot();
    let mut engine = PredictEngine::shipped();
    let first = engine.predict_batch(&reqs).expect("valid grid batch");
    let second = engine.predict_batch(&reqs).expect("valid grid batch");
    let after = atomics_repro::obs::profile::global().snapshot();

    for ((a, b), c) in plain.iter().zip(&first).zip(&second) {
        assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
        assert_eq!(b.latency_ns.to_bits(), c.latency_ns.to_bits());
        assert_eq!(b.bandwidth_gbs.to_bits(), c.bandwidth_gbs.to_bits());
    }
    // The repeat pass hits the LRU; the counters reach the global profile
    // (other tests share it, so assert deltas only).
    assert!(
        after.lru_hits + after.lru_misses >= before.lru_hits + before.lru_misses + 2 * 24,
        "LRU probes recorded: before={before:?} after={after:?}"
    );
    assert!(after.lru_hits >= before.lru_hits + 24, "repeat pass hits");
}

// ---------------------------------------------------------------------
// Chrome trace-event JSON: structural validation without a JSON crate.
// ---------------------------------------------------------------------

/// Minimal recursive-descent JSON syntax check (objects, arrays, strings
/// with escapes, numbers, literals). Returns the rest on success.
fn skip_ws(s: &[u8], mut i: usize) -> usize {
    while i < s.len() && matches!(s[i], b' ' | b'\t' | b'\n' | b'\r') {
        i += 1;
    }
    i
}

fn parse_value(s: &[u8], i: usize) -> Result<usize, String> {
    let i = skip_ws(s, i);
    let Some(&c) = s.get(i) else {
        return Err("unexpected end".into());
    };
    match c {
        b'{' => {
            let mut i = skip_ws(s, i + 1);
            if s.get(i) == Some(&b'}') {
                return Ok(i + 1);
            }
            loop {
                i = parse_string(s, skip_ws(s, i))?;
                i = skip_ws(s, i);
                if s.get(i) != Some(&b':') {
                    return Err(format!("expected ':' at {i}"));
                }
                i = parse_value(s, i + 1)?;
                i = skip_ws(s, i);
                match s.get(i) {
                    Some(&b',') => i += 1,
                    Some(&b'}') => return Ok(i + 1),
                    _ => return Err(format!("expected ',' or '}}' at {i}")),
                }
            }
        }
        b'[' => {
            let mut i = skip_ws(s, i + 1);
            if s.get(i) == Some(&b']') {
                return Ok(i + 1);
            }
            loop {
                i = parse_value(s, i)?;
                i = skip_ws(s, i);
                match s.get(i) {
                    Some(&b',') => i += 1,
                    Some(&b']') => return Ok(i + 1),
                    _ => return Err(format!("expected ',' or ']' at {i}")),
                }
            }
        }
        b'"' => parse_string(s, i),
        b't' if s[i..].starts_with(b"true") => Ok(i + 4),
        b'f' if s[i..].starts_with(b"false") => Ok(i + 5),
        b'n' if s[i..].starts_with(b"null") => Ok(i + 4),
        b'-' | b'0'..=b'9' => {
            let mut j = i + 1;
            while j < s.len()
                && matches!(s[j], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
            {
                j += 1;
            }
            Ok(j)
        }
        c => Err(format!("unexpected byte {c:#x} at {i}")),
    }
}

fn parse_string(s: &[u8], i: usize) -> Result<usize, String> {
    if s.get(i) != Some(&b'"') {
        return Err(format!("expected string at {i}"));
    }
    let mut i = i + 1;
    while let Some(&c) = s.get(i) {
        match c {
            b'"' => return Ok(i + 1),
            b'\\' => i += 2,
            _ => i += 1,
        }
    }
    Err("unterminated string".into())
}

fn assert_valid_json(doc: &str) {
    let bytes = doc.as_bytes();
    let end = parse_value(bytes, 0).unwrap_or_else(|e| panic!("invalid JSON: {e}"));
    assert_eq!(skip_ws(bytes, end), bytes.len(), "trailing garbage after document");
}

/// A real routed contention run through the Chrome sink: the document
/// parses, and its phase counts reconcile with the metrics registry —
/// one `"X"` slice per grant, one `"b"`/`"e"` pair per hand-off, two
/// `"C"` samples per link busy window, one `"i"` instant per steady
/// transition.
#[test]
fn chrome_trace_json_parses_and_counts_reconcile() {
    let mut cfg = arch::ivybridge();
    cfg.fabric = Fabric::routed_for(&cfg);
    let mut sink = Tee(ChromeTrace::new("trace test"), Metrics::new());
    let mut m = Machine::new(cfg.clone());
    let _ = run_model_sink(
        &mut m,
        &mut RunArena::new(),
        4,
        OpKind::Cas,
        OPS,
        SteadyMode::On,
        &mut sink,
    );
    let Tee(chrome, metrics) = sink;
    assert!(!chrome.is_empty(), "a contended run emits events");
    let doc = chrome.to_json();
    assert_valid_json(&doc);

    let count = |needle: &str| doc.matches(needle).count() as u64;
    assert_eq!(count("\"ph\":\"X\""), metrics.grants(), "grant slices");
    assert_eq!(count("\"ph\":\"b\""), metrics.handoffs(), "hand-off span begins");
    assert_eq!(count("\"ph\":\"e\""), metrics.handoffs(), "hand-off span ends");
    assert_eq!(count("\"ph\":\"C\""), 2 * metrics.link_windows(), "link samples");
    assert_eq!(
        count("\"ph\":\"i\""),
        metrics.steady_history().len() as u64,
        "steady instants"
    );
    assert!(metrics.handoffs() > 0, "4 contended threads migrate the line");
    assert!(metrics.link_windows() > 0, "routed fabric reports busy windows");
}
