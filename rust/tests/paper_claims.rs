//! Integration tests over the whole stack: the paper's qualitative claims
//! must hold end-to-end (simulator → benchmarks → model), on every testbed
//! where the paper states them.

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::bandwidth::BandwidthBench;
use atomics_repro::bench::latency::LatencyBench;
use atomics_repro::bench::placement::{PrepLocality, PrepState};
use atomics_repro::coordinator::dataset::collect_latency_dataset;
use atomics_repro::model::features::dot;
use atomics_repro::model::params::Theta;
use atomics_repro::util::stats::nrmse;

const KB16: usize = 16 << 10;
const KB64: usize = 64 << 10;

fn lat(cfg: &atomics_repro::sim::MachineConfig, op: OpKind, st: PrepState, loc: PrepLocality, sz: usize) -> f64 {
    LatencyBench::new(op, st, loc).run_once(cfg, sz).unwrap()
}

/// §5.1.4 headline: "the latency of CAS, FAA, and SWP is in most cases
/// identical" — consensus numbers buy nothing.
#[test]
fn consensus_number_does_not_change_latency_class() {
    for cfg in arch::all() {
        for st in [PrepState::E, PrepState::M] {
            let c = lat(&cfg, OpKind::Cas, st, PrepLocality::OnChip, KB64);
            let f = lat(&cfg, OpKind::Faa, st, PrepLocality::OnChip, KB64);
            let s = lat(&cfg, OpKind::Swp, st, PrepLocality::OnChip, KB64);
            let spread = (c - f).abs().max((s - f).abs());
            let base = f.max(1.0);
            assert!(
                spread / base < 0.25,
                "{}: CAS {c:.1} FAA {f:.1} SWP {s:.1} (state {st:?})",
                cfg.name
            );
        }
    }
}

/// §5.2: atomics bandwidth is 5–30× below plain writes on every testbed.
#[test]
fn atomics_bandwidth_5_to_30x_below_writes() {
    for cfg in arch::all() {
        let w = BandwidthBench::new(OpKind::Write, PrepState::M, PrepLocality::Local)
            .run_once(&cfg, KB16)
            .unwrap();
        let f = BandwidthBench::new(OpKind::Faa, PrepState::M, PrepLocality::Local)
            .run_once(&cfg, KB16)
            .unwrap();
        let ratio = w / f;
        assert!(
            (3.0..60.0).contains(&ratio),
            "{}: write {w:.2} GB/s vs FAA {f:.2} GB/s (x{ratio:.1})",
            cfg.name
        );
    }
}

/// §5.1.1: atomics are ≈5–10 ns slower than reads on Intel E/M states.
#[test]
fn intel_atomic_read_gap() {
    for cfg in [arch::haswell(), arch::ivybridge()] {
        let r = lat(&cfg, OpKind::Read, PrepState::M, PrepLocality::Local, KB16);
        let a = lat(&cfg, OpKind::Swp, PrepState::M, PrepLocality::Local, KB16);
        let gap = a - r;
        assert!((2.0..14.0).contains(&gap), "{}: gap {gap:.1}", cfg.name);
    }
}

/// §5.1.2: Bulldozer S/O atomics pay the remote invalidation broadcast even
/// with die-local sharers; Intel does not.
#[test]
fn bulldozer_pays_remote_broadcast_intel_does_not() {
    let amd = arch::bulldozer();
    let s = lat(&amd, OpKind::Cas, PrepState::S, PrepLocality::SharedL2, KB64);
    let e = lat(&amd, OpKind::Cas, PrepState::E, PrepLocality::SharedL2, KB64);
    assert!(s - e > 40.0, "AMD broadcast: E {e:.1} vs S {s:.1}");

    let intel = arch::haswell();
    let s = lat(&intel, OpKind::Cas, PrepState::S, PrepLocality::OnChip, KB64);
    let e = lat(&intel, OpKind::Cas, PrepState::E, PrepLocality::OnChip, KB64);
    assert!(
        (s - e).abs() < 25.0,
        "Intel tracks sharers: E {e:.1} vs S {s:.1}"
    );
}

/// §6.2.1/§6.2.2: with *die-local* sharers (the scenario that motivates the
/// proposals) both fixes eliminate the broadcast penalty; the shipping
/// MOESI still broadcasts because it cannot prove locality.
#[test]
fn proposed_extensions_remove_broadcast_penalty() {
    use atomics_repro::bench::placement::SharerPlacement;
    let measure = |cfg: &atomics_repro::sim::MachineConfig| {
        let mut b = LatencyBench::new(OpKind::Cas, PrepState::S, PrepLocality::SharedL2);
        b.sharer = SharerPlacement::SameDie;
        b.run_once(cfg, KB64).unwrap()
    };
    let b = measure(&arch::bulldozer());
    let o = measure(&arch::bulldozer_with_extensions(true, false, false));
    let h = measure(&arch::bulldozer_with_extensions(false, true, false));
    assert!(b - o > 30.0, "OL/SL: {b:.1} -> {o:.1}");
    assert!(b - h > 30.0, "HTA tracking: {b:.1} -> {h:.1}");
}

/// §6.2.3: FastLock restores write-buffer overlap for independent atomics.
#[test]
fn fastlock_improves_independent_atomic_bandwidth() {
    let base = arch::bulldozer();
    let fl = arch::bulldozer_with_extensions(false, false, true);
    let b = BandwidthBench::new(OpKind::Faa, PrepState::M, PrepLocality::Local)
        .run_once(&base, KB16)
        .unwrap();
    let f = BandwidthBench::new(OpKind::Faa, PrepState::M, PrepLocality::Local)
        .run_once(&fl, KB16)
        .unwrap();
    assert!(f >= b, "FastLock {f:.2} vs lock {b:.2} GB/s");
}

/// §5: the model tracks the simulator within NRMSE thresholds per series on
/// the E/M states (the paper's own validation discusses the S-state and
/// HT-Assist deviations).
#[test]
fn model_nrmse_on_exclusive_states() {
    for cfg in [arch::haswell(), arch::ivybridge()] {
        let ds = collect_latency_dataset(&cfg, &[16 << 10, 128 << 10, 2 << 20]);
        let theta = Theta::from_config(&cfg);
        let em: Vec<&_> = ds
            .iter()
            .filter(|d| {
                matches!(
                    d.query.state,
                    atomics_repro::model::ModelState::E | atomics_repro::model::ModelState::M
                )
            })
            .collect();
        let pred: Vec<f64> = em.iter().map(|d| dot(&d.features, &theta.to_vec())).collect();
        let obs: Vec<f64> = em.iter().map(|d| d.measured_ns).collect();
        let v = nrmse(&pred, &obs);
        assert!(v < 0.30, "{}: E/M NRMSE {:.1}%", cfg.name, v * 100.0);
    }
}

/// Fig. 7: 128-bit CAS penalty exists on Bulldozer, not on Intel.
#[test]
fn operand_width_penalty_amd_only() {
    use atomics_repro::bench::operand::width_comparison;
    let (s64, s128) =
        width_comparison(&arch::bulldozer(), PrepState::M, PrepLocality::Local, &[KB64]).unwrap();
    assert!(s128.points[0].value - s64.points[0].value > 10.0);
    let (s64, s128) =
        width_comparison(&arch::haswell(), PrepState::M, PrepLocality::Local, &[KB64]).unwrap();
    assert!((s128.points[0].value - s64.points[0].value).abs() < 1.0);
}

/// §5.7: unaligned atomics lock the bus on every testbed.
#[test]
fn unaligned_atomics_bus_lock_everywhere() {
    for cfg in arch::all() {
        let a = LatencyBench::new(OpKind::Cas, PrepState::M, PrepLocality::Local)
            .run_once(&cfg, KB16)
            .unwrap();
        let u = atomics_repro::bench::unaligned::unaligned_latency(
            &cfg,
            OpKind::Cas,
            PrepState::M,
            PrepLocality::Local,
            KB16,
        )
        .unwrap();
        assert!(u > a + 0.8 * cfg.unaligned.bus_lock_ns, "{}: {a:.0} vs {u:.0}", cfg.name);
    }
}
