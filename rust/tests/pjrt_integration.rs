//! Cross-layer integration: the Rust analytical model, the Pallas-kernel
//! HLO (via PJRT), and the AOT NRMSE executable must agree numerically.
//! These tests skip gracefully when `make artifacts` has not run.

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::coordinator::dataset::collect_latency_dataset;
use atomics_repro::model::features::{dot, featurize};
use atomics_repro::model::params::{Theta, THETA_DIM};
use atomics_repro::model::query::{ModelState, Query};
use atomics_repro::runtime::{Batch, Runtime, BATCH_ROWS};
use atomics_repro::sim::timing::Level;
use atomics_repro::sim::topology::Distance;

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !std::path::Path::new(&dir).join("predict.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(dir).expect("artifacts load"))
}

/// The AOT predict executable and the Rust featurization agree on every
/// (op, state, level, distance) combination of every architecture.
#[test]
fn pjrt_predict_agrees_with_rust_model() {
    let Some(rt) = runtime() else { return };
    for cfg in arch::all() {
        let theta = Theta::from_config(&cfg);
        let theta32: [f32; THETA_DIM] = std::array::from_fn(|i| theta.to_vec()[i] as f32);
        let mut queries = Vec::new();
        for op in [OpKind::Read, OpKind::Cas, OpKind::Faa, OpKind::Swp] {
            for state in [ModelState::E, ModelState::M, ModelState::S] {
                for level in [Level::L1, Level::L2, Level::L3, Level::Memory] {
                    for dist in [Distance::Local, Distance::SameDie, Distance::OtherSocket] {
                        queries.push(Query::new(op, state, level, dist));
                    }
                }
            }
        }
        let mut features = vec![0f32; BATCH_ROWS * THETA_DIM];
        for (i, q) in queries.iter().enumerate() {
            let f = featurize(&cfg, q);
            for j in 0..THETA_DIM {
                features[i * THETA_DIM + j] = f[j] as f32;
            }
        }
        let out = rt.predict(&features, &theta32).unwrap();
        for (i, q) in queries.iter().enumerate() {
            let rust = dot(&featurize(&cfg, q), &theta.to_vec());
            let pjrt = f64::from(out[i]);
            assert!(
                (rust - pjrt).abs() < 1e-3 * rust.abs().max(1.0),
                "{} {:?}: rust {rust} vs pjrt {pjrt}",
                cfg.name,
                q
            );
        }
    }
}

/// End-to-end Table-2 style flow on a small dataset: measure → featurize →
/// fit via PJRT → the fitted model predicts the measurements better than a
/// zero model and with NRMSE comparable to the seeded analytical model.
#[test]
fn fit_improves_over_uninformed_start() {
    use atomics_repro::coordinator::fit::{fit_theta, FitCfg};
    let Some(rt) = runtime() else { return };
    let cfg = arch::haswell();
    let ds = collect_latency_dataset(&cfg, &[16 << 10, 1 << 20]);
    let rows: Vec<([f64; THETA_DIM], f64)> =
        ds.iter().map(|d| (d.features, d.measured_ns)).collect();
    let zero = Theta::from_vec(&[0.0; THETA_DIM]);
    let report = fit_theta(
        &rt,
        cfg.name,
        &ds,
        zero,
        FitCfg { lr: 1e-3, max_iters: 600, tol: 1e-7 },
    )
    .unwrap();
    // NRMSE of the fitted theta via the AOT executable
    let theta32: [f32; THETA_DIM] = std::array::from_fn(|i| report.theta.to_vec()[i] as f32);
    let batch = &Batch::pack(&rows)[0];
    let pred = rt.predict(&batch.features, &theta32).unwrap();
    let v = rt.nrmse(&pred, &batch.targets, &batch.mask).unwrap();
    assert!(v < 0.5, "fitted-from-zero NRMSE {v}");
}

/// The NRMSE executable and the Rust Eq. 12 implementation agree on real
/// benchmark data.
#[test]
fn nrmse_paths_agree_on_benchmark_data() {
    let Some(rt) = runtime() else { return };
    let cfg = arch::ivybridge();
    let ds = collect_latency_dataset(&cfg, &[64 << 10]);
    let theta = Theta::from_config(&cfg);
    let rows: Vec<([f64; THETA_DIM], f64)> =
        ds.iter().map(|d| (d.features, d.measured_ns)).collect();
    let batch = &Batch::pack(&rows)[0];
    let theta32: [f32; THETA_DIM] = std::array::from_fn(|i| theta.to_vec()[i] as f32);
    let pred = rt.predict(&batch.features, &theta32).unwrap();
    let pjrt = rt.nrmse(&pred, &batch.targets, &batch.mask).unwrap();
    let rust = atomics_repro::util::stats::nrmse(
        &pred[..batch.n_valid].iter().map(|&x| f64::from(x)).collect::<Vec<_>>(),
        &batch.targets[..batch.n_valid].iter().map(|&x| f64::from(x)).collect::<Vec<_>>(),
    );
    assert!(
        (f64::from(pjrt) - rust).abs() < 1e-4,
        "pjrt {pjrt} vs rust {rust}"
    );
}
