//! Integration tests for the native fit & calibration subsystem
//! (`crate::fit`): exact recovery on every architecture's real design
//! matrix, gradient-descent agreement, offline end-to-end fits of
//! simulator measurements, and calibrator determinism + residual gates.

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::placement::PrepLocality;
use atomics_repro::coordinator::dataset::{
    collect_latency_dataset, fit_sizes, fit_sizes_fast, states_for, DataPoint,
};
use atomics_repro::data::fig8_targets::targets_for;
use atomics_repro::fit::backend::rows_of;
use atomics_repro::fit::calibrate::{calibrate, plateau_bandwidth, CalibrationCfg};
use atomics_repro::fit::solver::{gradient_descent, masked_mse, GdCfg};
use atomics_repro::fit::{FitBackend, FitCfg, NativeFit};
use atomics_repro::model::features::featurize_sized;
use atomics_repro::model::params::{Theta, THETA_DIM};
use atomics_repro::model::query::Query;
use atomics_repro::sim::timing::Level;
use atomics_repro::sim::MachineConfig;

/// A *noiseless* dataset over the architecture's real fit grid: the same
/// (op × state × locality × size) queries the measurement path walks,
/// with targets computed analytically from `theta` — so the generating θ
/// is the unique least-squares solution (up to absent columns, which
/// [`Theta::from_config`] already zeroes).
fn synthetic_dataset(cfg: &MachineConfig, theta: &Theta) -> Vec<DataPoint> {
    let tv = theta.to_vec();
    let mut out = Vec::new();
    for op in [OpKind::Read, OpKind::Cas, OpKind::Faa, OpKind::Swp] {
        for state in states_for(cfg) {
            for locality in PrepLocality::available(&cfg.topology) {
                for &size in &fit_sizes(cfg) {
                    let query =
                        Query::new(op, state.to_model(), Level::L1, locality.to_distance());
                    let (features, dominant) = featurize_sized(cfg, &query, size);
                    let mut query = query;
                    query.loc.level = dominant;
                    let y: f64 = features.iter().zip(&tv).map(|(a, b)| a * b).sum();
                    out.push(DataPoint {
                        query,
                        features,
                        measured_ns: y,
                        buffer_bytes: size,
                        series: format!("synthetic {op:?} {state:?} {locality:?}"),
                    });
                }
            }
        }
    }
    out
}

/// The tentpole guarantee: from noiseless data on the real design matrix
/// the native solver recovers the Table 2 seed θ to ≤1e-9 relative on
/// all four architectures, starting from zero knowledge (θ₀ = 0). Absent
/// parameters (Haswell's H, Phi's R_L3) have zero feature columns *and*
/// zero seed values, so pinning them to the init recovers them too.
#[test]
fn native_solver_recovers_seed_theta_exactly_on_all_arches() {
    for cfg in arch::all() {
        let seed = Theta::from_config(&cfg);
        let ds = synthetic_dataset(&cfg, &seed);
        assert!(ds.len() >= 3 * THETA_DIM, "{}: grid too small", cfg.name);
        let zero = Theta::from_vec(&[0.0; THETA_DIM]);
        let r = NativeFit.fit(cfg.name, &ds, zero, &FitCfg::default()).unwrap();
        for ((got, want), name) in
            r.theta.to_vec().iter().zip(seed.to_vec()).zip(Theta::NAMES)
        {
            assert!(
                (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                "{} {name}: fitted {got} vs seed {want}",
                cfg.name
            );
        }
        assert!(r.final_loss < 1e-12, "{}: noiseless loss {}", cfg.name, r.final_loss);
    }
}

/// Native-vs-GD agreement: the seed θ is a stationary point of the
/// projected descent on its own noiseless data (zero gradient, zero
/// projection pressure), and from a perturbed start the descent walks
/// back to the closed-form answer on the real Haswell design matrix.
#[test]
fn gradient_descent_agrees_with_closed_form_on_real_grid() {
    let cfg = arch::haswell();
    let seed = Theta::from_config(&cfg);
    let ds = synthetic_dataset(&cfg, &seed);
    let rows = rows_of(&ds);

    // Stationarity: starting at the truth, the descent stays there.
    let stay = gradient_descent(&rows, &seed.to_vec(), GdCfg::default());
    for (got, want) in stay.theta.iter().zip(seed.to_vec()) {
        assert!((got - want).abs() < 1e-9, "seed must be stationary: {got} vs {want}");
    }

    // Agreement in direction: from a perturbed start the descent moves
    // decisively toward the closed-form minimizer (the exact minimum of
    // the same loss) — the dominant error components die within the
    // iteration budget even if the flattest direction converges slowly.
    let perturbed: [f64; THETA_DIM] =
        std::array::from_fn(|i| seed.to_vec()[i] * 1.3 + 0.5);
    let start_loss = masked_mse(&rows, &perturbed);
    let gd = gradient_descent(&rows, &perturbed, GdCfg::default());
    let closed = NativeFit.fit(cfg.name, &ds, seed, &FitCfg::default()).unwrap();
    assert!(closed.final_loss < 1e-12, "closed form is exact on noiseless data");
    assert!(
        gd.loss < 0.05 * start_loss,
        "descent must close most of the gap to the closed form: {} of {start_loss}",
        gd.loss
    );
    assert!(gd.theta.iter().all(|&x| x >= 0.0), "projection respected");
}

/// The acceptance criterion for `repro fit`: real simulator measurements,
/// all four architectures, zero PJRT — and the fitted θ is physical,
/// anchored near Table 2, and a strict improvement over the seed in
/// masked MSE (the O residuals the linear model cannot express are what
/// remains).
#[test]
fn native_fit_produces_table2_theta_offline_for_all_arches() {
    for cfg in arch::all() {
        let ds = collect_latency_dataset(&cfg, &fit_sizes_fast(&cfg));
        let seed = Theta::from_config(&cfg);
        let r = NativeFit.fit(cfg.name, &ds, seed, &FitCfg::default()).unwrap();
        assert_eq!(r.backend, "native", "{}", cfg.name);
        assert_eq!(r.n_points, ds.len());
        let fitted = r.theta.to_vec();
        assert!(
            fitted.iter().all(|x| x.is_finite() && *x >= 0.0),
            "{}: unphysical θ {fitted:?}",
            cfg.name
        );
        // Reads carry no O residual, so they anchor R_L1 near Table 2.
        assert!(
            (r.theta.r_l1 - seed.r_l1).abs() < 0.5 * seed.r_l1 + 1.0,
            "{}: R_L1 fitted {} vs seed {}",
            cfg.name,
            r.theta.r_l1,
            seed.r_l1
        );
        assert!(
            (r.theta.e_cas - seed.e_cas).abs() < 8.0,
            "{}: E(CAS) fitted {} vs seed {}",
            cfg.name,
            r.theta.e_cas,
            seed.e_cas
        );
        let rows = rows_of(&ds);
        // (1e-3 ns² slack: clamping sub-ns numerical negatives to zero
        // can nudge the closed-form optimum by strictly less than this.)
        assert!(
            r.final_loss <= masked_mse(&rows, &seed.to_vec()) + 1e-3,
            "{}: fit {} worse than seed {}",
            cfg.name,
            r.final_loss,
            masked_mse(&rows, &seed.to_vec())
        );
    }
}

/// Reduced calibration search for test runtimes (the CLI default uses
/// 2000 ops/thread and a finer schedule).
fn test_calibration() -> CalibrationCfg {
    CalibrationCfg {
        ops_per_thread: 200,
        lo: 0.02,
        hi: 0.98,
        coarse: 7,
        refine: 10,
        run_threads: 1,
        ..CalibrationCfg::default()
    }
}

/// The calibrator is bit-deterministic and lands every architecture's
/// Fig. 8 plateau residual under the gate — the `repro calibrate`
/// acceptance criterion. The fitted overlap must also genuinely beat the
/// search endpoints (the optimizer optimized something).
#[test]
fn calibrator_is_deterministic_and_residual_below_threshold() {
    for cfg in arch::all() {
        let targets = targets_for(cfg.name);
        assert!(!targets.is_empty(), "{}: no targets", cfg.name);
        let ccfg = test_calibration();
        let a = calibrate(&cfg, &targets, &ccfg).unwrap();
        let b = calibrate(&cfg, &targets, &ccfg).unwrap();
        assert_eq!(
            a.fitted_overlap.to_bits(),
            b.fitted_overlap.to_bits(),
            "{}: calibration must be deterministic",
            cfg.name
        );
        assert_eq!(a.mean_rel_residual.to_bits(), b.mean_rel_residual.to_bits());
        assert!(
            (ccfg.lo..=ccfg.hi).contains(&a.fitted_overlap),
            "{}: fitted {} outside search interval",
            cfg.name,
            a.fitted_overlap
        );
        assert!(
            a.mean_rel_residual < 0.30,
            "{}: plateau residual {:.1}% above the 30% gate (fitted overlap {})",
            cfg.name,
            a.mean_rel_residual * 100.0,
            a.fitted_overlap
        );

        // Sanity of the search: the fit beats both interval endpoints.
        let residual_at = |ov: f64| -> f64 {
            targets
                .iter()
                .map(|t| {
                    let got =
                        plateau_bandwidth(&cfg, ov, t.op, t.threads, ccfg.ops_per_thread);
                    (got - t.gbs).abs() / t.gbs
                })
                .sum::<f64>()
                / targets.len() as f64
        };
        for endpoint in [ccfg.lo, ccfg.hi] {
            assert!(
                a.mean_rel_residual <= residual_at(endpoint) + 1e-12,
                "{}: fitted residual {} worse than endpoint {} ({})",
                cfg.name,
                a.mean_rel_residual,
                endpoint,
                residual_at(endpoint)
            );
        }
    }
}

/// The shipped per-architecture `handoff_overlap` values track what the
/// calibrator chooses. The tight (30%) gate above holds for the *fitted*
/// value, which is robust to the exact hand-off distance mix the
/// deterministic schedule produces; the shipped defaults are sanity-
/// gated more loosely (they were derived from the schedule's transfer
/// mix analytically — `repro calibrate` is the authoritative refit, and
/// even a fully socket-interleaved schedule stays under this bound).
#[test]
fn shipped_overlaps_reproduce_the_plateau_targets() {
    for cfg in arch::all() {
        let targets = targets_for(cfg.name);
        let mean: f64 = targets
            .iter()
            .map(|t| {
                let got =
                    plateau_bandwidth(&cfg, cfg.handoff_overlap, t.op, t.threads, 400);
                (got - t.gbs).abs() / t.gbs
            })
            .sum::<f64>()
            / targets.len() as f64;
        assert!(
            mean < 0.60,
            "{}: shipped overlap {} misses the Fig. 8 plateaus by {:.1}%",
            cfg.name,
            cfg.handoff_overlap,
            mean * 100.0
        );
        // and the shipped values are genuinely per-architecture
        assert!((0.0..1.0).contains(&cfg.handoff_overlap), "{}", cfg.name);
    }
    let overlaps: Vec<f64> = arch::all().iter().map(|c| c.handoff_overlap).collect();
    assert!(
        overlaps.windows(2).any(|w| w[0] != w[1]),
        "per-arch calibration must not collapse back to one global value: {overlaps:?}"
    );
}
