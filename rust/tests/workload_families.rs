//! Integration tests for the scenario-diversity workload families
//! (successful CAS, FAA delta, false sharing, locks/queues): executor
//! determinism across worker counts, the paper-shaped inequalities each
//! family must reproduce, and the family registry's CLI contract.

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::falseshare::{run_false_sharing, Layout};
use atomics_repro::bench::latency::LatencyBench;
use atomics_repro::bench::locks::{run_lock, run_lock_stepwise, LockKind};
use atomics_repro::bench::placement::{PrepLocality, PrepState};
use atomics_repro::sim::Machine;
use atomics_repro::sweep::{jobs_for, SuccessfulCas, SweepExecutor, Workload};

const SIZES: [usize; 2] = [16 << 10, 256 << 10];

/// Every new family produces bit-identical results with 1 worker and with
/// 4 workers (the acceptance bar every figure rests on).
#[test]
fn new_families_deterministic_across_executor_threads() {
    // Haswell (4 cores) keeps the thread-axis families cheap in debug
    // builds; larger-topology determinism is pinned by the unit tests in
    // bench::locks / bench::falseshare (Bulldozer, 8 threads).
    let configs = [arch::haswell()];
    for family in ["cas-success", "faa-delta", "false-sharing", "locks"] {
        let jobs = jobs_for(family, &configs, &SIZES).expect("known family");
        assert!(!jobs.is_empty(), "{family} must expand");
        let single = SweepExecutor::new(1).run(&jobs);
        let parallel = SweepExecutor::new(4).run(&jobs);
        assert_eq!(single.len(), parallel.len());
        for (a, b) in single.iter().zip(&parallel) {
            assert_eq!(a.name, b.name);
            assert!(a.failures.is_empty(), "{family}/{}: {:?}", a.name, a.failures);
            assert!(b.failures.is_empty(), "{family}/{}: {:?}", b.name, b.failures);
            for ((xa, va), (xb, vb)) in a.points.iter().zip(&b.points) {
                assert_eq!(xa, xb);
                assert_eq!(
                    va.map(f64::to_bits),
                    vb.map(f64::to_bits),
                    "{family}: {} [{}] at x={}",
                    a.name,
                    a.arch,
                    xa
                );
            }
        }
    }
}

/// A successful CAS does strictly more work than a read (RFO + compare +
/// write), so its latency must dominate the read baseline in every state.
#[test]
fn successful_cas_at_least_as_slow_as_read_per_state() {
    for cfg in [arch::haswell(), arch::bulldozer()] {
        for state in [PrepState::E, PrepState::M, PrepState::S] {
            for locality in [PrepLocality::Local, PrepLocality::OnChip] {
                let mut m = Machine::new(cfg.clone());
                let read = LatencyBench::new(OpKind::Read, state, locality)
                    .run_on(&mut m, 16 << 10)
                    .unwrap();
                m.reset();
                let scas = SuccessfulCas { state, locality }
                    .measure(&mut m, 16 << 10)
                    .unwrap();
                assert!(
                    scas >= read,
                    "{} {} {}: successful CAS {scas} vs read {read}",
                    cfg.name,
                    state.label(),
                    locality.label()
                );
            }
        }
    }
}

/// The packed (falsely shared) layout must show more invalidation traffic
/// and more line migrations than the padded layout, and lose bandwidth —
/// with the coherence machinery, not an assertion, producing the numbers.
#[test]
fn false_sharing_shows_more_invalidations_than_padded() {
    for cfg in [arch::haswell(), arch::bulldozer()] {
        let mut m = Machine::new(cfg);
        let n = m.cfg.topology.n_cores.min(8);
        let packed = run_false_sharing(&mut m, Layout::Packed, n, 300).unwrap();
        let padded = run_false_sharing(&mut m, Layout::Padded, n, 300).unwrap();
        assert!(
            packed.total_invalidations() > padded.total_invalidations(),
            "{}: packed {} vs padded {} invalidations",
            m.cfg.name,
            packed.total_invalidations(),
            padded.total_invalidations()
        );
        assert!(packed.total_line_hops() > padded.total_line_hops(), "{}", m.cfg.name);
        assert!(packed.bandwidth_gbs < padded.bandwidth_gbs, "{}", m.cfg.name);
    }
}

/// The CAS/SWP-based primitives waste more attempts as rivals multiply
/// (the Dice et al. contention effect); FAA-based tickets never fail.
#[test]
fn lock_family_fail_ratio_grows_with_thread_count() {
    let mut m = Machine::new(arch::ivybridge());
    for kind in [LockKind::TasSpin, LockKind::Mpsc] {
        let low = run_lock(&mut m, kind, 2, 40).unwrap();
        let high = run_lock(&mut m, kind, 8, 40).unwrap();
        assert!(
            high.fail_ratio() > low.fail_ratio(),
            "{}: {} vs {}",
            kind.label(),
            high.fail_ratio(),
            low.fail_ratio()
        );
    }
    let t2 = run_lock(&mut m, LockKind::Ticket, 2, 40).unwrap();
    let t8 = run_lock(&mut m, LockKind::Ticket, 8, 40).unwrap();
    assert_eq!(t2.fail_ratio(), 0.0);
    assert_eq!(t8.fail_ratio(), 0.0);
}

/// The lock family is priced by the multi-core scheduler: per-thread
/// ContentionStats must be populated and show real coherence traffic.
#[test]
fn lock_family_carries_per_thread_engine_stats() {
    let mut m = Machine::new(arch::ivybridge());
    for kind in LockKind::ALL {
        let r = run_lock(&mut m, kind, 4, 40).unwrap();
        assert_eq!(r.per_thread.len(), 4, "{}", kind.label());
        assert!(
            r.total_line_hops() > 0,
            "{}: the hot word must migrate between cores",
            kind.label()
        );
        assert!(
            r.per_thread.iter().all(|s| s.latency_ns > 0.0),
            "{}: every thread pays engine latency",
            kind.label()
        );
    }
}

/// THE golden gate for spin fast-forward: the production scheduler
/// (memoized poll replay, flat event structures) and the stepwise
/// reference scheduler (every poll a full engine walk) produce
/// bit-identical results on the real §6.1 programs — spin-heavy ticket
/// locks and consumer polls included — across protocols with and without
/// write combining.
#[test]
fn lock_results_identical_fast_and_stepwise() {
    for cfg in [arch::ivybridge(), arch::bulldozer(), arch::xeonphi()] {
        let mut m = Machine::new(cfg);
        for kind in LockKind::ALL {
            let fast = run_lock(&mut m, kind, 8, 30).unwrap();
            let slow = run_lock_stepwise(&mut m, kind, 8, 30).unwrap();
            let name = format!("{} on {}", kind.label(), m.cfg.name);
            assert_eq!(
                fast.acq_per_sec.to_bits(),
                slow.acq_per_sec.to_bits(),
                "{name}: fast {} vs stepwise {}",
                fast.acq_per_sec,
                slow.acq_per_sec
            );
            assert_eq!(fast.elapsed_ns.to_bits(), slow.elapsed_ns.to_bits(), "{name}");
            assert_eq!(fast.per_thread, slow.per_thread, "{name}");
            assert_eq!(fast.attempts, slow.attempts, "{name}");
            assert_eq!(fast.failed_attempts, slow.failed_attempts, "{name}");
            assert_eq!(fast.spin_reads, slow.spin_reads, "{name}");
            assert_eq!(fast.acquisitions, slow.acquisitions, "{name}");
        }
    }
}

/// The steady-state extension of the gate above: with cycle detection
/// and period fast-forward armed (`SteadyMode::On`), every lock kind
/// still produces bit-identical results to the plain fast scheduler —
/// which the previous test pins to the stepwise reference, closing the
/// stepwise ≡ fast ≡ fast+steady chain.
#[test]
fn lock_results_identical_with_steady_fast_forward() {
    use atomics_repro::bench::locks::run_lock_in_steady;
    use atomics_repro::sim::{RunArena, SteadyMode};

    for cfg in [arch::ivybridge(), arch::bulldozer(), arch::xeonphi()] {
        let mut m = Machine::new(cfg);
        for kind in LockKind::ALL {
            let plain = run_lock(&mut m, kind, 8, 30).unwrap();
            let (steady, info) = run_lock_in_steady(
                &mut m,
                &mut RunArena::new(),
                kind,
                8,
                30,
                SteadyMode::On,
            )
            .unwrap();
            let name = format!("{} on {} (steady)", kind.label(), m.cfg.name);
            assert!(!info.aborted, "{name}: replay contradicted a verified period");
            assert_eq!(
                plain.acq_per_sec.to_bits(),
                steady.acq_per_sec.to_bits(),
                "{name}: plain {} vs steady {}",
                plain.acq_per_sec,
                steady.acq_per_sec
            );
            assert_eq!(plain.elapsed_ns.to_bits(), steady.elapsed_ns.to_bits(), "{name}");
            assert_eq!(plain.per_thread, steady.per_thread, "{name}");
            assert_eq!(plain.attempts, steady.attempts, "{name}");
            assert_eq!(plain.failed_attempts, steady.failed_attempts, "{name}");
            assert_eq!(plain.spin_reads, steady.spin_reads, "{name}");
            assert_eq!(plain.acquisitions, steady.acquisitions, "{name}");
        }
    }
}

/// Direct lock runs and executor-pooled runs agree bit-for-bit (the
/// fresh-machine-semantics contract of run_program).
#[test]
fn lock_results_identical_on_pooled_and_fresh_machines() {
    let cfg = arch::haswell();
    let jobs = jobs_for("locks", &[cfg.clone()], &SIZES).unwrap();
    let out = SweepExecutor::new(2).run(&jobs);
    let tas = out
        .iter()
        .find(|o| o.name.contains("tas-spinlock"))
        .expect("tas series present");
    for &(x, v) in &tas.points {
        let mut m = Machine::new(cfg.clone());
        let direct = run_lock(
            &mut m,
            LockKind::TasSpin,
            x as usize,
            atomics_repro::bench::locks::ACQ_PER_THREAD,
        )
        .unwrap();
        assert_eq!(
            v.map(f64::to_bits),
            Some((direct.acq_per_sec / 1e6).to_bits()),
            "threads={x}"
        );
    }
}
