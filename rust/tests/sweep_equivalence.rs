//! Golden equivalence tests for the sweep subsystem: the parallel
//! `Workload`-based executor must reproduce *byte-identical* `Series`
//! values to the historical per-module serial loops (which allocate a fresh
//! `Machine` per point), and its results must not depend on the thread
//! count. These tests are the contract that lets every figure and dataset
//! run through the executor without changing a single reported number.

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::bandwidth::BandwidthBench;
use atomics_repro::bench::latency::LatencyBench;
use atomics_repro::bench::placement::{PrepLocality, PrepState};
use atomics_repro::coordinator::dataset::collect_latency_dataset;
use atomics_repro::sweep::{SweepExecutor, SweepJob, SweepPlan, Workload};
use std::sync::Arc;

const SIZES: [usize; 3] = [4 << 10, 64 << 10, 1 << 20];

fn assert_series_bits_equal(
    golden: &atomics_repro::bench::Series,
    got: &atomics_repro::bench::Series,
    context: &str,
) {
    assert_eq!(golden.points.len(), got.points.len(), "{context}: point count");
    for (a, b) in golden.points.iter().zip(&got.points) {
        assert_eq!(a.buffer_bytes, b.buffer_bytes, "{context}: x coordinate");
        assert_eq!(
            a.value.to_bits(),
            b.value.to_bits(),
            "{context} at {} bytes: serial {} vs executor {}",
            a.buffer_bytes,
            a.value,
            b.value
        );
    }
}

/// The executor (reset-and-reuse machines, parallel workers) reproduces the
/// serial per-point-fresh-machine latency sweep bit-for-bit on all four
/// architectures.
#[test]
fn latency_sweep_identical_to_serial_loops_on_all_arches() {
    for cfg in arch::all() {
        let bench = LatencyBench::new(OpKind::Faa, PrepState::M, PrepLocality::Local);
        let golden = bench.sweep(&cfg, &SIZES).expect("local always available");
        let jobs = vec![SweepJob::sized(&cfg, Arc::new(bench), &SIZES)];
        let out = SweepExecutor::new(4).run(&jobs);
        let series = out[0].series().expect("local always available");
        assert_series_bits_equal(&golden, &series, cfg.name);
    }
}

/// Same for a bandwidth sweep (store-buffer paths, clock-delta measurement).
#[test]
fn bandwidth_sweep_identical_to_serial_loops_on_all_arches() {
    for cfg in arch::all() {
        let bench = BandwidthBench::new(OpKind::Cas, PrepState::M, PrepLocality::Local);
        let golden = bench.sweep(&cfg, &SIZES).expect("local always available");
        let jobs = vec![SweepJob::sized(&cfg, Arc::new(bench), &SIZES)];
        let out = SweepExecutor::new(4).run(&jobs);
        let series = out[0].series().expect("local always available");
        assert_series_bits_equal(&golden, &series, cfg.name);
    }
}

/// A shared-state sweep exercises the invalidation machinery and the
/// multi-core preparation phase; it must survive the round trip too.
#[test]
fn shared_state_latency_sweep_identical() {
    let cfg = arch::bulldozer();
    let bench = LatencyBench::new(OpKind::Cas, PrepState::S, PrepLocality::SharedL2);
    let golden = bench.sweep(&cfg, &SIZES).expect("shared L2 exists on Bulldozer");
    let out = SweepExecutor::new(8)
        .run(&[SweepJob::sized(&cfg, Arc::new(bench), &SIZES)]);
    assert_series_bits_equal(&golden, &out[0].series().unwrap(), "Bulldozer S/SharedL2");
}

/// Determinism across thread counts: a full latency grid produces the same
/// bits with 1 worker and with 8 workers.
#[test]
fn thread_count_does_not_change_results() {
    let plan = SweepPlan::latency(vec![arch::haswell(), arch::xeonphi()], vec![4 << 10, 256 << 10]);
    let jobs = plan.expand();
    let single = SweepExecutor::new(1).run(&jobs);
    let parallel = SweepExecutor::new(8).run(&jobs);
    assert_eq!(single.len(), parallel.len());
    for (a, b) in single.iter().zip(&parallel) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.points.len(), b.points.len());
        for ((xa, va), (xb, vb)) in a.points.iter().zip(&b.points) {
            assert_eq!(xa, xb);
            assert_eq!(
                va.map(f64::to_bits),
                vb.map(f64::to_bits),
                "{} [{}] at x={}",
                a.name,
                a.arch,
                xa
            );
        }
    }
}

/// THE golden gate for the prep-reuse fast path: for every registered
/// workload family, the executor (chunked prep-affinity scheduling,
/// snapshot-restored prepared machines, pooled resets) reproduces the
/// fresh-machine-per-point reference bit-for-bit. A family whose fast
/// path drifts by one ULP fails here.
#[test]
fn every_family_identical_to_fresh_machine_runs() {
    let sizes = [4 << 10, 64 << 10];
    for cfg in [arch::haswell(), arch::bulldozer()] {
        for family in atomics_repro::sweep::family_names() {
            // Bulldozer thread-axis grids (32-core ladders) are the unit
            // tests' turf; here they'd dominate the runtime without adding
            // prep-path coverage (thread-axis workloads declare no prep).
            if cfg.name == "Bulldozer" && family != "latency" && family != "cas-success" {
                continue;
            }
            let jobs = atomics_repro::sweep::jobs_for(family, &[cfg.clone()], &sizes)
                .expect("registered family");
            let out = SweepExecutor::new(4).run(&jobs);
            assert_eq!(out.len(), jobs.len());
            for (job, o) in jobs.iter().zip(&out) {
                assert!(o.failures.is_empty(), "{family}/{}: {:?}", o.name, o.failures);
                for &(x, got) in &o.points {
                    let mut fresh = atomics_repro::sim::Machine::new(job.cfg.clone());
                    let want = job.workload.measure(&mut fresh, x);
                    assert_eq!(
                        want.map(f64::to_bits),
                        got.map(f64::to_bits),
                        "{} {family}: {} at x={x}: fresh {want:?} vs executor {got:?}",
                        cfg.name,
                        o.name
                    );
                }
            }
        }
    }
}

/// The executor-backed dataset collection produces the same rows, in the
/// same order, as two consecutive invocations of itself (guarding against
/// any pool-state leakage between runs).
#[test]
fn dataset_collection_is_reproducible() {
    let cfg = arch::haswell();
    let sizes = [16 << 10, 2 << 20];
    let a = collect_latency_dataset(&cfg, &sizes);
    let b = collect_latency_dataset(&cfg, &sizes);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.series, y.series);
        assert_eq!(x.buffer_bytes, y.buffer_bytes);
        assert_eq!(x.measured_ns.to_bits(), y.measured_ns.to_bits());
    }
}
