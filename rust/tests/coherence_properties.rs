//! Property-based tests over the coherence engine: random operation
//! sequences on random cores/addresses must preserve the global invariants
//! (DESIGN.md §6) on every architecture and protocol variant.

use atomics_repro::arch;
use atomics_repro::atomics::{Op, Width};
use atomics_repro::sim::Machine;
use atomics_repro::util::prop::{for_all_with, default_cases};
use atomics_repro::util::rng::Rng;

fn random_op(rng: &mut Rng) -> Op {
    match rng.below(6) {
        0 => Op::Read,
        1 => Op::Write { value: rng.next_u64() % 100 },
        2 => Op::Cas { expected: rng.next_u64() % 4, new: rng.next_u64() % 100, fetched_operands: 1 },
        3 => Op::Faa { delta: rng.next_u64() % 10 },
        4 => Op::Swp { value: rng.next_u64() % 100 },
        _ => Op::Read,
    }
}

/// Run `ops` random operations, checking the invariants periodically.
fn random_workout(m: &mut Machine, rng: &mut Rng, ops: usize, lines: u64) {
    let n_cores = m.cfg.topology.n_cores as u64;
    for i in 0..ops {
        let core = rng.below(n_cores) as usize;
        let addr = 0x10_0000 + rng.below(lines) * 64 + rng.below(8) * 8;
        let op = random_op(rng);
        let a = m.access(core, op, addr, Width::W64);
        assert!(a.latency > 0.0, "latency must be positive ({op:?})");
        assert!(a.latency < 1e5, "latency absurd: {} ({op:?})", a.latency);
        if i % 64 == 0 {
            if let Err(e) = m.check_invariants() {
                panic!("invariant violated after {i} ops: {e}");
            }
        }
    }
    m.check_invariants().unwrap();
}

#[test]
fn invariants_hold_on_haswell() {
    for_all_with(0xA1, default_cases(), |rng| {
        let mut m = Machine::new(arch::haswell());
        random_workout(&mut m, rng, 300, 64);
    });
}

#[test]
fn invariants_hold_on_ivybridge() {
    for_all_with(0xA2, default_cases(), |rng| {
        let mut m = Machine::new(arch::ivybridge());
        random_workout(&mut m, rng, 300, 64);
    });
}

#[test]
fn invariants_hold_on_bulldozer() {
    for_all_with(0xA3, default_cases(), |rng| {
        let mut m = Machine::new(arch::bulldozer());
        random_workout(&mut m, rng, 300, 64);
    });
}

#[test]
fn invariants_hold_on_xeonphi() {
    for_all_with(0xA4, default_cases(), |rng| {
        let mut m = Machine::new(arch::xeonphi());
        random_workout(&mut m, rng, 300, 64);
    });
}

#[test]
fn invariants_hold_with_extensions() {
    for_all_with(0xA5, default_cases(), |rng| {
        let mut m = Machine::new(arch::bulldozer_with_extensions(true, true, true));
        random_workout(&mut m, rng, 300, 64);
    });
}

#[test]
fn invariants_hold_with_prefetchers() {
    for_all_with(0xA6, default_cases(), |rng| {
        let mut cfg = arch::haswell();
        cfg.mechanisms.hw_prefetcher = true;
        cfg.mechanisms.adjacent_line = true;
        let mut m = Machine::new(cfg);
        random_workout(&mut m, rng, 300, 64);
    });
}

/// Data semantics: the memory store must agree with a host-side shadow
/// model under arbitrary interleavings.
#[test]
fn data_values_match_shadow_model() {
    for_all_with(0xB1, default_cases(), |rng| {
        let mut m = Machine::new(arch::haswell());
        let mut shadow = std::collections::HashMap::<u64, u64>::new();
        for _ in 0..200 {
            let core = rng.below(4) as usize;
            let addr = 0x20_0000 + rng.below(16) * 8;
            let op = random_op(rng);
            let before = *shadow.get(&addr).unwrap_or(&0);
            let (after, returned, modified) = op.apply(before);
            let a = m.access64(core, op, addr);
            assert_eq!(a.value, returned, "returned value for {op:?} at {addr:#x}");
            assert_eq!(a.modified, modified);
            shadow.insert(addr, after);
        }
        for (&addr, &v) in &shadow {
            assert_eq!(m.mem.read(addr), v, "divergence at {addr:#x}");
        }
    });
}

/// Determinism: identical seeds and op sequences give identical latencies.
#[test]
fn engine_is_deterministic() {
    for_all_with(0xC1, 16, |rng| {
        let seed = rng.next_u64();
        let run = |seed: u64| -> Vec<u64> {
            let mut rng = Rng::new(seed);
            let mut m = Machine::new(arch::bulldozer());
            (0..200)
                .map(|_| {
                    let core = rng.below(32) as usize;
                    let addr = 0x400_000 + rng.below(32) * 64;
                    m.access64(core, random_op(&mut rng), addr).latency.to_bits()
                })
                .collect()
        };
        assert_eq!(run(seed), run(seed));
    });
}

/// Monotonic virtual clocks.
#[test]
fn clocks_never_regress() {
    for_all_with(0xD1, 16, |rng| {
        let mut m = Machine::new(arch::xeonphi());
        let mut last = vec![0.0f64; 61];
        for _ in 0..200 {
            let core = rng.below(61) as usize;
            let addr = 0x80_0000 + rng.below(32) * 64;
            m.access64(core, random_op(rng), addr);
            let now = m.clock_of(core);
            assert!(now >= last[core], "clock regressed on core {core}");
            last[core] = now;
        }
    });
}

/// BFS trees from random Kronecker graphs are always valid, under both
/// claim protocols and any thread count.
#[test]
fn bfs_always_produces_valid_trees() {
    use atomics_repro::graph::bfs::validate_tree;
    use atomics_repro::graph::{kronecker_edges, parallel_bfs, BfsMode, Csr};
    for_all_with(0xE1, 12, |rng| {
        let scale = 6 + rng.below(3) as u32;
        let seed = rng.next_u64();
        let threads = 1 + rng.below(4) as usize;
        let csr = Csr::from_edges(1 << scale, &kronecker_edges(scale, seed));
        let Some(root) = csr.first_non_isolated() else { return };
        for mode in [BfsMode::Cas, BfsMode::Swp] {
            let mut m = Machine::new(arch::haswell());
            let r = parallel_bfs(&mut m, &csr, root, threads, mode);
            validate_tree(&csr, root, &r.parent)
                .unwrap_or_else(|e| panic!("{mode:?} scale {scale} seed {seed:#x}: {e}"));
        }
    });
}
