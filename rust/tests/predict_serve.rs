//! Serving-layer golden tests (DESIGN.md §11): the batched `repro
//! predict` engine must be **bit-identical** to the one-off scalar model
//! path on every testbed, with or without the cache, at any streaming
//! width/chunking, and its wire formats must round-trip through the
//! crate's single-source label parsers.

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::model::analytical;
use atomics_repro::model::params::Theta;
use atomics_repro::model::query::{ModelState, Query, QueryBuilder};
use atomics_repro::serve::{
    canonical_grid, parse_batch, parse_theta_csv, ArchId, PredictEngine, PredictRequest,
    PredictResponse, ThetaSource, ThetaTable, PREDICT_SCHEMA_VERSION, RESPONSE_CSV_HEADER,
};
use atomics_repro::sim::timing::Level;
use atomics_repro::sim::topology::Distance;
use atomics_repro::sweep::RunPool;

/// Every canonical grid point of every testbed as a request batch.
fn full_grid() -> Vec<PredictRequest> {
    let mut reqs = Vec::new();
    for a in ArchId::ALL {
        for query in canonical_grid(&a.config()) {
            reqs.push(PredictRequest { arch: a, query });
        }
    }
    reqs
}

#[test]
fn golden_batched_equals_one_off_on_all_arches() {
    let reqs = full_grid();
    assert!(reqs.len() > 300, "grid unexpectedly small: {}", reqs.len());
    let mut engine = PredictEngine::shipped();
    let got = engine.predict_batch(&reqs).unwrap();
    for (r, resp) in reqs.iter().zip(&got) {
        // the one-off path the CLI pays per query: rebuild the config,
        // reseed θ, evaluate the scalar model
        let cfg = r.arch.config();
        let theta = Theta::from_config(&cfg);
        let latency = analytical::latency(&cfg, &r.query, &theta, true);
        let bandwidth = analytical::bandwidth_distinct_lines(&cfg, &r.query, &theta);
        assert_eq!(
            resp.latency_ns.to_bits(),
            latency.to_bits(),
            "{}: {:?}",
            cfg.name,
            r.query
        );
        assert_eq!(
            resp.bandwidth_gbs.to_bits(),
            bandwidth.to_bits(),
            "{}: {:?}",
            cfg.name,
            r.query
        );
    }
}

#[test]
fn cache_hit_path_is_bit_identical_to_cold_path() {
    let reqs = full_grid();
    let mut uncached = PredictEngine::shipped().without_cache();
    let want = uncached.predict_batch(&reqs).unwrap();

    let mut cached = PredictEngine::shipped();
    let cold = cached.predict_batch(&reqs).unwrap();
    let warm = cached.predict_batch(&reqs).unwrap();
    assert_eq!(cold, want);
    assert_eq!(warm, want);
    let stats = cached.cache_stats();
    assert_eq!(stats.misses, reqs.len() as u64, "first pass all misses");
    assert_eq!(stats.hits, reqs.len() as u64, "second pass all hits");

    // single-point predictions agree with the batch too
    let mut single = PredictEngine::shipped();
    for (r, w) in reqs.iter().zip(&want).step_by(17) {
        let got = single.predict(r).unwrap();
        assert_eq!(got.latency_ns.to_bits(), w.latency_ns.to_bits(), "{r:?}");
    }
}

#[test]
fn streaming_is_bit_identical_and_ordered_at_any_width() {
    let reqs = full_grid();
    let mut engine = PredictEngine::shipped();
    let want = engine.predict_batch(&reqs).unwrap();
    for threads in [1, 2, 4] {
        let pool = RunPool::new(threads);
        let mut got: Vec<PredictResponse> = Vec::new();
        let mut first_indices = Vec::new();
        engine
            .predict_streaming(&reqs, &pool, 50, |first, responses| {
                first_indices.push(first);
                got.extend(responses);
            })
            .unwrap();
        assert_eq!(got.len(), want.len(), "threads={threads}");
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.latency_ns.to_bits(), w.latency_ns.to_bits(), "threads={threads}");
            assert_eq!(g.arch, w.arch);
            assert_eq!(g.query, w.query);
        }
        let expect: Vec<usize> = (0..reqs.len()).step_by(50).collect();
        assert_eq!(first_indices, expect, "threads={threads}: input order");
    }
}

#[test]
fn csv_and_json_round_trip_through_the_engine() {
    // emit a response stream as CSV, parse it back, predict again: fixed
    // point after one round
    let reqs: Vec<PredictRequest> = full_grid().into_iter().step_by(23).collect();
    let mut engine = PredictEngine::shipped();
    let responses = engine.predict_batch(&reqs).unwrap();

    let mut csv = atomics_repro::util::csv::Csv::new(&RESPONSE_CSV_HEADER);
    for r in &responses {
        csv.row(&r.csv_row());
    }
    let back = parse_batch(&csv.to_string(), None).unwrap();
    assert_eq!(back, reqs, "CSV round-trip");

    let json: String =
        responses.iter().map(|r| r.to_json() + "\n").collect();
    assert!(json.contains(&format!("\"v\":{PREDICT_SCHEMA_VERSION},")));
    let back = parse_batch(&json, None).unwrap();
    assert_eq!(back, reqs, "JSON round-trip");

    // and predictions over the round-tripped batch are bit-identical
    let again = engine.predict_batch(&back).unwrap();
    for (a, b) in again.iter().zip(&responses) {
        assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
    }
}

#[test]
fn malformed_batches_fail_with_line_numbers() {
    let text = "op,state,level,distance,arch\n\
                cas,E,L1,local,haswell\n\
                frob,E,L1,local,haswell\n\
                cas,E,L1,nowhere,haswell\n";
    let err = parse_batch(text, None).unwrap_err();
    let lines: Vec<usize> = err.errors.iter().map(|&(l, _)| l).collect();
    assert_eq!(lines, vec![3, 4]);

    // arch-level validation failures carry request ordinals
    let ok = PredictRequest::new(
        ArchId::Haswell,
        Query::new(OpKind::Faa, ModelState::M, Level::L2, Distance::Local),
    );
    let no_l3 = PredictRequest::new(
        ArchId::XeonPhi,
        Query::new(OpKind::Faa, ModelState::M, Level::L3, Distance::Local),
    );
    let mut engine = PredictEngine::shipped();
    let err = engine.predict_batch(&[ok, no_l3]).unwrap_err();
    assert_eq!(err.errors.len(), 1);
    assert_eq!(err.errors[0].0, 2);
    assert!(err.errors[0].1.contains("no L3"), "{err}");
}

#[test]
fn builder_and_parsers_share_one_label_table() {
    // every label of every enum round-trips through the batch parser
    for a in ArchId::ALL {
        let cfg = a.config();
        for q in canonical_grid(&cfg).into_iter().step_by(7) {
            // the distance cell is quoted: the splitter must accept quoted
            // cells whether or not the label needs them
            let distance = format!("\"{}\"", q.loc.distance.label());
            let invalidate = q
                .invalidate_distance
                .map(|d| d.label().to_string())
                .unwrap_or_else(|| "-".into());
            let text = format!(
                "op,state,level,distance,invalidate,arch\n{},{},{},{},{},{}\n",
                q.op.label(),
                q.state.label(),
                q.loc.level.label(),
                distance,
                invalidate,
                a.slug(),
            );
            let parsed = parse_batch(&text, None).unwrap();
            assert_eq!(parsed, vec![PredictRequest { arch: a, query: q }], "{text}");
        }
    }
    // the builder validates what the parser validates
    assert!(QueryBuilder::new(OpKind::Read, ModelState::S)
        .invalidate(Distance::SameDie)
        .build()
        .is_err());
}

#[test]
fn fitted_theta_overrides_shipped_and_falls_back() {
    let dir = std::env::temp_dir().join("atomics_repro_predict_serve_theta");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // write a haswell θ CSV with every parameter bumped by 1 ns
    let cfg = arch::haswell();
    let seed = Theta::from_config(&cfg).to_vec();
    let mut csv = atomics_repro::util::csv::Csv::new(&["param", "paper_ns", "fitted_ns"]);
    for (i, name) in Theta::NAMES.iter().enumerate() {
        csv.row(&[name.to_string(), seed[i].to_string(), (seed[i] + 1.0).to_string()]);
    }
    let path = dir.join("fit_theta_haswell.csv");
    csv.write(&path).unwrap();
    // sanity: the file as written parses back
    let parsed = parse_theta_csv(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(parsed.r_l1, seed[0] + 1.0);

    let table = ThetaTable::with_fitted_from(dir.to_str().unwrap());
    assert!(matches!(table.source(ArchId::Haswell), ThetaSource::Fitted { .. }));
    assert_eq!(*table.source(ArchId::Bulldozer), ThetaSource::Shipped);

    // predictions with the fitted table differ from shipped on haswell
    // (local L1 read = r_l1, so exactly +1 ns) but match on bulldozer
    let q = Query::new(OpKind::Read, ModelState::E, Level::L1, Distance::Local);
    let mut fitted = PredictEngine::new(table);
    let mut shipped = PredictEngine::shipped();
    let f = fitted.predict(&PredictRequest::new(ArchId::Haswell, q)).unwrap();
    let s = shipped.predict(&PredictRequest::new(ArchId::Haswell, q)).unwrap();
    assert!((f.latency_ns - s.latency_ns - 1.0).abs() < 1e-12);
    let fb = fitted.predict(&PredictRequest::new(ArchId::Bulldozer, q)).unwrap();
    let sb = shipped.predict(&PredictRequest::new(ArchId::Bulldozer, q)).unwrap();
    assert_eq!(fb.latency_ns.to_bits(), sb.latency_ns.to_bits());
    let _ = std::fs::remove_dir_all(&dir);
}
