//! Topology-invariant properties of the routed interconnect fabric
//! (`sim::fabric`) and its engine wiring:
//!
//! * route symmetry — A→B and B→A have the same hop count on every
//!   architecture's topology (ring arcs and HT meshes are symmetric,
//!   and the Phi's tag-directory detour visits the same arcs each way);
//! * conservation — every message that enters a link leaves it by the
//!   end of the run, on every architecture;
//! * scalar bit-identity — the default `Fabric::Scalar` pricing is the
//!   pre-fabric engine: deterministic, identical under an explicitly
//!   installed `Scalar`, identical across fresh/reused arenas, and
//!   carrying no link traffic (absolute plateau values stay pinned by
//!   `tests/contention_engine.rs`);
//! * determinism — routed runs are bit-identical across run-pool widths
//!   1/2/4 (virtual time never depends on host scheduling);
//! * pipelining — concurrent hand-offs on disjoint Phi ring legs are
//!   each charged only the injection leg, and a routed contended-FAA
//!   run finishes far faster than the serialized sum of full route
//!   traversals (the effect `--topology routed` exists to model).

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::sim::fabric::{Fabric, FabricState, Topology as _};
use atomics_repro::sim::multicore::{run_contention, run_contention_in, RunArena};
use atomics_repro::sim::Machine;
use atomics_repro::sweep::RunPool;

/// Cache lines exercising distinct Phi tag-directory stops (the third
/// maps high addresses, catching modulo mistakes).
const LINES: [u64; 3] = [0, 7, 0x5000_0000 / 64];

fn routed(cfg: &atomics_repro::sim::MachineConfig) -> Fabric {
    let f = Fabric::routed_for(cfg);
    assert!(f.is_routed(), "{}: routed_for must produce a routed fabric", cfg.name);
    f
}

#[test]
fn routes_are_symmetric_in_hop_count_on_every_arch() {
    for cfg in arch::all() {
        let fab = routed(&cfg);
        let rt = fab.routed().unwrap();
        let n = cfg.topology.n_cores;
        let (mut fwd, mut rev) = (Vec::new(), Vec::new());
        for &line in &LINES {
            for a in (0..n).step_by(3) {
                for b in (0..n).step_by(5) {
                    rt.topo.route_into(a, b, line, &mut fwd);
                    rt.topo.route_into(b, a, line, &mut rev);
                    assert_eq!(
                        fwd.len(),
                        rev.len(),
                        "{}: hop count {a}->{b} vs {b}->{a} (line {line})",
                        cfg.name
                    );
                    for &l in fwd.iter().chain(&rev) {
                        assert!(l < rt.topo.links().len(), "{}: link index in bounds", cfg.name);
                    }
                }
            }
        }
    }
}

#[test]
fn every_message_entering_a_link_leaves_it() {
    for cfg in arch::all() {
        let threads = cfg.topology.n_cores.min(16);
        let mut rcfg = cfg.clone();
        rcfg.fabric = routed(&cfg);
        let mut m = Machine::new(rcfg);
        let r = run_contention(&mut m, threads, OpKind::Faa, 100);
        let mut entered_total = 0u64;
        for l in &r.links {
            assert_eq!(l.entered, l.left, "{} link '{}': conservation", cfg.name, l.label);
            assert_eq!(l.bytes, l.entered * 64, "{} link '{}': 64B messages", cfg.name, l.label);
            entered_total += l.entered;
        }
        assert!(
            entered_total > 0,
            "{}: {threads} contending threads must put traffic on the fabric",
            cfg.name
        );
    }
}

#[test]
fn scalar_default_is_bit_identical_and_carries_no_links() {
    for cfg in arch::all() {
        let threads = cfg.topology.n_cores.min(8);
        // default config (Fabric::Scalar is the shipped default)
        let base = run_contention(&mut Machine::new(cfg.clone()), threads, OpKind::Cas, 150);
        assert!(base.links.is_empty(), "{}: scalar runs carry no link stats", cfg.name);
        // repeated run: deterministic
        let again = run_contention(&mut Machine::new(cfg.clone()), threads, OpKind::Cas, 150);
        assert_eq!(base, again, "{}: scalar runs are deterministic", cfg.name);
        // explicitly installed Scalar: the same engine path
        let mut scfg = cfg.clone();
        scfg.fabric = Fabric::Scalar;
        let explicit = run_contention(&mut Machine::new(scfg), threads, OpKind::Cas, 150);
        assert_eq!(base, explicit, "{}: explicit Scalar == default", cfg.name);
        // reused arena: bit-identical to the fresh-arena path
        let mut m = Machine::new(cfg.clone());
        let mut arena = RunArena::new();
        run_contention_in(&mut m, &mut arena, threads, OpKind::Faa, 150);
        let reused = run_contention_in(&mut m, &mut arena, threads, OpKind::Cas, 150);
        assert_eq!(base, reused, "{}: reused arena == fresh arena", cfg.name);
    }
}

#[test]
fn routed_runs_are_bit_identical_across_run_pool_widths() {
    let cfg = arch::xeonphi();
    let mut rcfg = cfg.clone();
    rcfg.fabric = routed(&cfg);
    let counts = [1usize, 2, 4, 8];
    let run = |width: usize| {
        RunPool::new(width).map(
            &counts,
            || (Machine::new(rcfg.clone()), RunArena::new()),
            |(m, arena), &n| run_contention_in(m, arena, n, OpKind::Faa, 150),
        )
    };
    let serial = run(1);
    assert!(serial.iter().all(|r| !r.links.is_empty()), "routed runs report links");
    for width in [2usize, 4] {
        assert_eq!(serial, run(width), "width {width} vs serial");
    }
}

#[test]
fn disjoint_phi_ring_handoffs_are_charged_only_the_injection_leg() {
    let cfg = arch::xeonphi();
    let fab = routed(&cfg);
    let rt = fab.routed().unwrap();
    let mut st = FabricState::new();
    st.ensure(rt.topo.links().len());
    // Two hand-offs at t=0 whose tag-directory routes share no link:
    // 0→1 via TD stop 10 and 30→31 via TD stop 40. Neither waits on the
    // other — each pays exactly the injection leg, and both message
    // trains are in flight at once (the pipelining the scalar model's
    // serialized hand-off charge cannot express).
    let a = st.handoff(rt, 0, 1, 10, 0.0);
    let b = st.handoff(rt, 30, 31, 40, 0.0);
    assert_eq!(a, rt.inject_ns, "first hand-off: no queue wait");
    assert_eq!(b, rt.inject_ns, "disjoint second hand-off: no queue wait");
    assert!(st.inflight_total() >= 2, "both trains in flight concurrently");
    let links = st.finish(rt, 1000.0);
    let entered: u64 = links.iter().map(|l| l.entered).sum();
    let left: u64 = links.iter().map(|l| l.left).sum();
    assert_eq!(entered, left, "finish drains every in-flight message");
    assert!(entered > 0);
}

#[test]
fn routed_phi_faa_beats_the_serialized_sum_of_route_traversals() {
    let cfg = arch::xeonphi();
    let mut rcfg = cfg.clone();
    rcfg.fabric = routed(&cfg);
    let mut m = Machine::new(rcfg);
    let r = run_contention(&mut m, 16, OpKind::Faa, 200);
    let total_ops = r.total_ops() as f64;
    // If every hand-off serialized behind the full ring + tag-directory
    // traversal (Table 2's H = 161.2 ns), the run could not finish before
    // ops × (E(FAA) + H). Route pricing charges senders only the local
    // injection leg, so concurrent FAAs overlap on the ring and the run
    // lands far below that bound.
    let serialized = total_ops * (cfg.timing.e_faa + cfg.timing.hop);
    assert!(
        r.elapsed_ns < 0.5 * serialized,
        "pipelined {} ns vs serialized bound {} ns",
        r.elapsed_ns,
        serialized
    );
}
