//! API-compatible stub of the XLA/PJRT binding surface used by
//! `atomics_repro::runtime`.
//!
//! The offline build image does not ship the native XLA libraries, so this
//! crate lets the workspace compile and test without them. Every entry
//! point that would need the real backend returns [`Error::Unavailable`];
//! `Runtime::load` therefore fails fast with a clear message and the CLI
//! degrades to paper-seed parameters (exactly the pre-existing "artifacts
//! not built" path). Dropping the real `xla` bindings into `vendor/xla`
//! re-enables the PJRT fit/predict path with no source changes elsewhere.

use std::fmt;

/// Stub error: the native backend is absent.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla backend unavailable in this build ({what}); \
                 vendor the real PJRT bindings under vendor/xla to enable it"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Host-side literal (dense array) handle.
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    /// Build a scalar f32 literal.
    pub fn scalar(_value: f32) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Extract the single element of a 1-tuple.
    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    /// Extract both elements of a 2-tuple.
    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        unavailable("Literal::to_tuple2")
    }

    /// Copy the literal out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module.
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file. Always unavailable in the stub, so loaders
    /// fail before any executable is constructed.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation ready for compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer returned by an execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled, loaded executable.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments, one result buffer list per device.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client. Unavailable in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_constructors_work() {
        let l = Literal::vec1(&[1.0, 2.0]);
        assert!(l.reshape(&[2, 1]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
        let _ = Literal::scalar(0.5);
    }
}
