//! Minimal offline-vendored subset of the `anyhow` API.
//!
//! The build environment has no crates.io access, so this crate provides
//! exactly the surface the repository uses: [`Error`], [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Like real anyhow, [`Error`] deliberately does **not** implement
//! `std::error::Error`, so the blanket `From<E: std::error::Error>`
//! conversion does not conflict with it.

use std::fmt;

/// A string-backed error with an optional chain of context messages.
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Create an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), context: Vec::new() }
    }

    fn push_context(mut self, c: String) -> Error {
        self.context.push(c);
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // outermost context first, root cause last — anyhow's ordering
        match self.context.last() {
            Some(c) => write!(f, "{c}: {}", self.msg),
            None => write!(f, "{}", self.msg),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.msg)?;
        if !self.context.is_empty() {
            writeln!(f, "\nCaused while:")?;
            for (i, c) in self.context.iter().enumerate() {
                writeln!(f, "  {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` specialized to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors, as in anyhow.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).push_context(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).push_context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn from_std_error_via_question_mark() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading artifact").unwrap_err();
        assert!(e.to_string().contains("loading artifact"));
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("no value {}", 7)).unwrap_err();
        assert!(e.to_string().contains("no value 7"));
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert!(f(2).is_ok());
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(5).unwrap_err().to_string().contains("condition failed"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
    }
}
