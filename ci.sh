#!/usr/bin/env bash
# CI gate for the atomics-repro workspace: format, build, test, smoke-sweep.
# Run from the repository root. Fails fast on the first broken step.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "(rustfmt not installed — skipping format check)"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== smoke: repro sweep --threads 2 (reduced grid) =="
./target/release/repro sweep --threads 2 --fast --family latency --arch haswell

echo "CI OK"
