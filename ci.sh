#!/usr/bin/env bash
# CI gate for the atomics-repro workspace: format, build, test, smoke-sweep.
# Run from the repository root. Fails fast on the first broken step.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "(rustfmt not installed — skipping format check)"
fi

echo "== cargo build --release (incl. examples) =="
cargo build --release
cargo build --release --examples

echo "== cargo test -q (unit + integration) =="
cargo test -q --lib --bins --tests

echo "== cargo doc --no-deps (warnings denied) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p atomics-repro --quiet

echo "== doc-tests =="
cargo test -q --doc -p atomics-repro

echo "== smoke: repro sweep --threads 2 (reduced grid) =="
./target/release/repro sweep --threads 2 --fast --family latency --arch haswell

echo "== smoke: repro contend (machine-accurate Fig. 8 path) =="
./target/release/repro contend --arch haswell --op cas --threads 2 --ops 200 --stats

echo "CI OK"
