#!/usr/bin/env bash
# CI gate for the atomics-repro workspace: format, lint, build, test, a
# smoke matrix over every workload family, and a bench-regression gate.
# Run from the repository root. Fails fast on the first broken step.
#
#   ./ci.sh                    full gate
#   ./ci.sh --update-baseline  additionally rewrite BENCH_baseline.json
#                              from this run (after an intentional perf
#                              change)
set -euo pipefail
cd "$(dirname "$0")"

GATE_ARGS=()
for arg in "$@"; do
    case "$arg" in
        --update-baseline) GATE_ARGS+=("--update-baseline") ;;
        *) echo "unknown ci.sh argument '$arg'" >&2; exit 2 ;;
    esac
done

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "(rustfmt not installed — skipping format check)"
fi

echo "== cargo clippy --all-targets (warnings denied) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "(clippy not installed — skipping lint check)"
fi

echo "== cargo build --release (incl. examples) =="
cargo build --release
cargo build --release --examples

echo "== cargo test -q (unit + integration) =="
cargo test -q --lib --bins --tests

echo "== cargo doc --no-deps (warnings denied) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p atomics-repro --quiet

echo "== doc-tests =="
cargo test -q --doc -p atomics-repro

# Smoke matrix: every workload family in the registry gets a reduced run,
# so no family can silently rot. The list is read from the binary itself
# (`repro sweep --list` prints the same table the CLI parses against).
for fam in $(./target/release/repro sweep --list); do
    echo "== smoke: repro sweep --family $fam (reduced grid, haswell) =="
    ./target/release/repro sweep --threads 2 --fast --family "$fam" --arch haswell
done

echo "== smoke: repro sweep --points 4 (deterministic budget thinning) =="
./target/release/repro sweep --threads 2 --fast --points 4 --family latency --arch haswell

echo "== smoke: repro contend (machine-accurate Fig. 8 path) =="
./target/release/repro contend --arch haswell --op cas --threads 2 --ops 200 --stats

echo "== smoke: repro locks (§6.1 lock/queue + false-sharing path) =="
./target/release/repro locks --arch haswell --threads 2 --acq 50 --stats

echo "== smoke: repro fit --backend native (offline Table 2 fit) =="
./target/release/repro fit --backend native --arch haswell

echo "== smoke: repro calibrate (contention-plateau calibrator) =="
./target/release/repro calibrate --arch haswell --ops 400

echo "== smoke: run-level parallelism (--run-threads run pool) =="
./target/release/repro contend --arch haswell --op faa --ops 200 --run-threads 2
./target/release/repro calibrate --arch haswell --ops 400 --run-threads 2

echo "== smoke: routed interconnect fabric (--topology routed) =="
./target/release/repro contend --arch phi --op faa --ops 200 --topology routed --stats
./target/release/repro calibrate --arch phi --topology routed --ops 300 --run-threads 2

echo "== smoke: steady-state fast-forward (--steady-state on) =="
./target/release/repro contend --arch haswell --op cas --threads 2 --ops 400 --steady-state on
./target/release/repro calibrate --arch haswell --steady-state on --ops 400

echo "== smoke: simulation tracing (--trace / repro trace, Chrome trace-event JSON) =="
TRACE_DIR=$(mktemp -d)
# boolean flags last: Args treats "--flag value" as flag=value
RESULTS_DIR="$TRACE_DIR" ./target/release/repro contend --arch haswell --op cas \
    --threads 2 --ops 200 --trace --stats
RESULTS_DIR="$TRACE_DIR" ./target/release/repro trace --arch phi --op faa \
    --threads 4 --ops 200 --topology routed
if command -v python3 >/dev/null 2>&1; then
    python3 - "$TRACE_DIR/trace_haswell.json" "$TRACE_DIR/trace_xeon_phi.json" <<'EOF'
import json, sys
for path in sys.argv[1:]:
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    assert events, f"{path}: no trace events"
    phases = {e["ph"] for e in events}
    assert "X" in phases, f"{path}: no grant slices"
    print(f"{path}: {len(events)} events OK")
EOF
else
    echo "(python3 not installed — skipping trace JSON validation)"
fi
rm -rf "$TRACE_DIR"

echo "== smoke: harness self-profiling (--profile) and leveled logging (REPRO_LOG) =="
./target/release/repro predict --grid --arch haswell --profile >/dev/null
# quiet mode may silence diagnostics but must leave stdout byte-identical
./target/release/repro contend --arch haswell --op faa --threads 2 --ops 200 >/tmp/contend_info.out
REPRO_LOG=quiet ./target/release/repro contend --arch haswell --op faa --threads 2 --ops 200 >/tmp/contend_quiet.out
cmp /tmp/contend_info.out /tmp/contend_quiet.out
rm -f /tmp/contend_info.out /tmp/contend_quiet.out

echo "== smoke: scripts/scalability.sh (2-rung contend ladder) =="
BIN=./target/release/repro scripts/scalability.sh --arch haswell --ops 300 --rungs "1 2"

echo "== smoke: repro predict (batched prediction serving) =="
# full canonical grid of one testbed, CSV out
./target/release/repro predict --grid --arch haswell >/dev/null
# a CSV batch through stdin, JSON-lines out, schema version checked
PREDICT_OUT=$(printf 'op,state,level,distance,arch\ncas,S,L3,on chip,haswell\nfaa,M,L2,local,ivy\n' \
    | ./target/release/repro predict --input - --json)
echo "$PREDICT_OUT" | grep -q '"v":1'

echo "== bench-regression gate (BENCH_sweep.json vs BENCH_baseline.json) =="
BENCH_FAST=1 cargo bench --bench bench_sweep
# cargo runs bench binaries with cwd = the package root, so the fresh
# results usually land in rust/; tolerate either location.
FRESH=BENCH_sweep.json
[ -f rust/BENCH_sweep.json ] && FRESH=rust/BENCH_sweep.json
if command -v python3 >/dev/null 2>&1; then
    python3 scripts/bench_gate.py BENCH_baseline.json "$FRESH" \
        --threshold=0.20 ${GATE_ARGS[@]+"${GATE_ARGS[@]}"}
else
    echo "(python3 not installed — skipping bench-regression gate)"
fi

echo "CI OK"
