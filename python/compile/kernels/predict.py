"""Layer-1 Pallas kernel: batched evaluation of the analytical latency model.

The model (paper Eq. 1-8) is linear in the parameter vector theta once a
query is featurized (see rust/src/model/features.rs):  `L = F @ theta`.
The kernel computes that matvec tiled over rows so the feature matrix
streams through VMEM block by block.

Hardware adaptation note (DESIGN.md §3): the paper targets x86 CPUs, so
there is no GPU kernel to port; the hot spot of *this* system is sweeping
thousands of model evaluations per figure.  The BlockSpec tiles rows in
chunks of `BLOCK_ROWS` = 128 — an MXU/VPU-friendly leading dimension — and
broadcasts the small theta tile to every grid step.  On CPU the kernel runs
under interpret=True (Mosaic custom-calls cannot execute on the CPU PJRT
plugin); the VMEM footprint per step is BLOCK_ROWS x FEATURE_DIM x 4 B
(features) + FEATURE_DIM x 4 (theta) + BLOCK_ROWS x 4 (out) ≈ 4.6 KiB,
far below the 16 MiB VMEM budget, leaving ample double-buffering headroom.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Feature dimension: [r_l1, r_l2, r_l3, hop, mem, e_cas, e_faa, e_swp]
# (must match rust/src/model/params.rs::THETA_DIM).
FEATURE_DIM = 8
BLOCK_ROWS = 128


def _predict_kernel(f_ref, theta_ref, out_ref):
    """One grid step: out[block] = F[block, :] @ theta."""
    f = f_ref[...]  # (BLOCK_ROWS, FEATURE_DIM)
    theta = theta_ref[...]  # (1, FEATURE_DIM)
    # Row-block matvec, expressed as a broadcast-multiply + lane reduction
    # (VPU-friendly; the MXU picks this up for larger K).
    out_ref[...] = jnp.sum(f * theta, axis=1)


@functools.partial(jax.jit, static_argnames=())
def predict(features, theta):
    """Latency predictions `features @ theta` via the Pallas kernel.

    features: f32[N, FEATURE_DIM] with N a multiple of BLOCK_ROWS.
    theta:    f32[FEATURE_DIM]
    returns:  f32[N]
    """
    n, k = features.shape
    assert k == FEATURE_DIM, f"feature dim {k} != {FEATURE_DIM}"
    assert n % BLOCK_ROWS == 0, f"N={n} must be a multiple of {BLOCK_ROWS}"
    grid = (n // BLOCK_ROWS,)
    theta2d = theta.reshape(1, FEATURE_DIM)
    return pl.pallas_call(
        _predict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, FEATURE_DIM), lambda i: (i, 0)),
            pl.BlockSpec((1, FEATURE_DIM), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(features, theta2d)
