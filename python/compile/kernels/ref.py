"""Pure-jnp oracle for the Pallas predict kernel (correctness reference).

Also used as the differentiable forward pass inside the fit step (pallas
interpret-mode kernels do not define a VJP, and the two are asserted
allclose by python/tests/test_kernel.py, so the gradients are taken through
mathematically identical code).
"""

import jax.numpy as jnp


def predict_ref(features, theta):
    """`features @ theta` — the linear analytical model (Eq. 1-8)."""
    return features @ theta


def nrmse_ref(predicted, observed, weights):
    """Weighted NRMSE (paper Eq. 12) ignoring masked-out (weight 0) rows."""
    w = weights
    n = jnp.maximum(jnp.sum(w), 1.0)
    mean_obs = jnp.sum(w * observed) / n
    mse = jnp.sum(w * (predicted - observed) ** 2) / n
    return jnp.sqrt(mse) / mean_obs
