"""AOT compile path: lower the L2 model entry points to HLO *text* for the
Rust PJRT runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate binds) rejects; the text
parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py and /opt/xla-example/README.md.

Usage:  python -m compile.aot --outdir ../artifacts
Writes: predict.hlo.txt, fit_step.hlo.txt, nrmse.hlo.txt, manifest.txt
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    """Lower every artifact; returns {name: hlo_text}."""
    args = model.example_args()
    fns = {
        "predict": model.predict,
        "fit_step": model.fit_step,
        "nrmse": model.nrmse,
    }
    out = {}
    for name, fn in fns.items():
        lowered = jax.jit(fn).lower(*args[name])
        out[name] = to_hlo_text(lowered)
    return out


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--outdir", default="../artifacts")
    parser.add_argument("--out", default=None, help="legacy single-file alias (writes predict)")
    ns = parser.parse_args()

    outdir = ns.outdir
    if ns.out is not None:
        outdir = os.path.dirname(ns.out) or "."
    os.makedirs(outdir, exist_ok=True)

    texts = lower_all()
    manifest = []
    for name, text in texts.items():
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(
            f"{name}: rows={model.BATCH_ROWS} features={model.__dict__['FEATURE_DIM'] if 'FEATURE_DIM' in model.__dict__ else 8} bytes={len(text)}"
        )
        print(f"wrote {len(text)} chars to {path}")
    # legacy alias expected by the original scaffold Makefile
    legacy = os.path.join(outdir, "model.hlo.txt")
    with open(legacy, "w") as f:
        f.write(texts["predict"])
    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


if __name__ == "__main__":
    main()
