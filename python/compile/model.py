"""Layer-2 JAX model: the analytical performance model (paper §4) as a
batched compute graph, plus the gradient fit step that recovers the Table 2
parameters from simulator measurements.

Three entry points are AOT-lowered by aot.py and executed from Rust via
PJRT (Python never runs at benchmark time):

* predict(features, theta)            -> latency[N]          (Pallas kernel)
* fit_step(features, y, w, theta, lr) -> (theta', loss)      (jax.grad)
* nrmse(pred, obs, w)                 -> scalar              (Eq. 12)

All shapes are static: N = BATCH_ROWS rows; callers pad with zero-weight
rows (weight vector w masks them out of the loss/metric).
"""

import jax
import jax.numpy as jnp

from .kernels.predict import BLOCK_ROWS, FEATURE_DIM, predict as predict_kernel
from .kernels.ref import nrmse_ref, predict_ref

# The static batch the artifacts are exported with. Figure sweeps produce at
# most a few hundred query rows; Rust pads to this.
BATCH_ROWS = 512
assert BATCH_ROWS % BLOCK_ROWS == 0


def predict(features, theta):
    """Batched latency prediction through the Pallas kernel (L = F @ theta)."""
    return predict_kernel(features, theta)


def weighted_mse(theta, features, y, w):
    """Masked mean-squared error of the linear model."""
    pred = predict_ref(features, theta)  # differentiable forward
    n = jnp.maximum(jnp.sum(w), 1.0)
    return jnp.sum(w * (pred - y) ** 2) / n


def fit_step(features, y, w, theta, lr):
    """One gradient-descent step on the masked MSE.

    Returns (theta', loss-before-step). Rust drives the loop and decides
    convergence; a non-negativity projection keeps the parameters physical
    (latencies cannot be negative).
    """
    loss, grad = jax.value_and_grad(weighted_mse)(theta, features, y, w)
    theta_new = jnp.maximum(theta - lr * grad, 0.0)
    return theta_new, loss


def nrmse(pred, obs, w):
    """Eq. 12 on masked rows."""
    return nrmse_ref(pred, obs, w)


def example_args():
    """ShapeDtypeStructs for AOT lowering."""
    f32 = jnp.float32
    feats = jax.ShapeDtypeStruct((BATCH_ROWS, FEATURE_DIM), f32)
    vec = jax.ShapeDtypeStruct((BATCH_ROWS,), f32)
    theta = jax.ShapeDtypeStruct((FEATURE_DIM,), f32)
    scalar = jax.ShapeDtypeStruct((), f32)
    return {
        "predict": (feats, theta),
        "fit_step": (feats, vec, vec, theta, scalar),
        "nrmse": (vec, vec, vec),
    }
