"""Kernel-vs-oracle correctness: the CORE numeric signal of the L1 layer.

Hypothesis sweeps shapes and value ranges of the Pallas predict kernel
against the pure-jnp reference; exact agreement is expected (identical
operation order on f32)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.predict import BLOCK_ROWS, FEATURE_DIM, predict
from compile.kernels.ref import predict_ref


def _random_case(rng, n):
    features = rng.uniform(-2.0, 2.0, size=(n, FEATURE_DIM)).astype(np.float32)
    theta = rng.uniform(0.0, 100.0, size=(FEATURE_DIM,)).astype(np.float32)
    return features, theta


class TestPredictKernel:
    @pytest.mark.parametrize("blocks", [1, 2, 4])
    def test_matches_reference_for_block_multiples(self, blocks):
        rng = np.random.default_rng(blocks)
        f, t = _random_case(rng, blocks * BLOCK_ROWS)
        got = np.asarray(predict(jnp.asarray(f), jnp.asarray(t)))
        want = np.asarray(predict_ref(jnp.asarray(f), jnp.asarray(t)))
        # f32 reduction order differs between the tiled kernel and the
        # reference matmul; agreement is to f32 round-off.
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_theta_gives_zero(self):
        f = jnp.ones((BLOCK_ROWS, FEATURE_DIM), jnp.float32)
        t = jnp.zeros((FEATURE_DIM,), jnp.float32)
        assert np.allclose(np.asarray(predict(f, t)), 0.0)

    def test_unit_features_sum_theta(self):
        f = jnp.ones((BLOCK_ROWS, FEATURE_DIM), jnp.float32)
        t = jnp.arange(FEATURE_DIM, dtype=jnp.float32)
        got = np.asarray(predict(f, t))
        assert np.allclose(got, float(np.arange(FEATURE_DIM).sum()))

    def test_rejects_non_multiple_rows(self):
        f = jnp.ones((BLOCK_ROWS + 1, FEATURE_DIM), jnp.float32)
        t = jnp.zeros((FEATURE_DIM,), jnp.float32)
        with pytest.raises(AssertionError):
            predict(f, t)

    def test_rejects_wrong_feature_dim(self):
        f = jnp.ones((BLOCK_ROWS, FEATURE_DIM + 1), jnp.float32)
        t = jnp.zeros((FEATURE_DIM + 1,), jnp.float32)
        with pytest.raises(AssertionError):
            predict(f, t)

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        blocks=st.integers(1, 3),
        scale=st.floats(0.1, 1000.0),
    )
    def test_hypothesis_sweep(self, seed, blocks, scale):
        rng = np.random.default_rng(seed)
        n = blocks * BLOCK_ROWS
        f = (rng.standard_normal((n, FEATURE_DIM)) * scale).astype(np.float32)
        t = (rng.standard_normal(FEATURE_DIM) * scale).astype(np.float32)
        got = np.asarray(predict(jnp.asarray(f), jnp.asarray(t)))
        want = np.asarray(f @ t)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-3 * scale)

    def test_paper_table2_haswell_row(self):
        # A hand-built feature row: local-L1 CAS on Haswell, Eq. 1 with
        # Table 2 seeds -> r_l1 + e_cas = 5.87 ns.
        theta = jnp.asarray(
            [1.17, 3.5, 10.3, 0.0, 65.0, 4.7, 5.6, 5.6], jnp.float32
        )
        row = np.zeros((BLOCK_ROWS, FEATURE_DIM), np.float32)
        row[0, 0] = 1.0  # r_l1
        row[0, 5] = 1.0  # e_cas
        got = np.asarray(predict(jnp.asarray(row), theta))
        assert abs(got[0] - 5.87) < 1e-4
        assert np.allclose(got[1:], 0.0)
