"""L2 model tests: fit-step convergence, NRMSE semantics, shape contracts."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.predict import FEATURE_DIM


def _synthetic(rng, n_valid, theta_true):
    """A padded batch whose first n_valid rows are real observations."""
    f = np.zeros((model.BATCH_ROWS, FEATURE_DIM), np.float32)
    y = np.zeros((model.BATCH_ROWS,), np.float32)
    w = np.zeros((model.BATCH_ROWS,), np.float32)
    f[:n_valid] = rng.uniform(0.0, 2.0, size=(n_valid, FEATURE_DIM))
    y[:n_valid] = f[:n_valid] @ theta_true
    w[:n_valid] = 1.0
    return jnp.asarray(f), jnp.asarray(y), jnp.asarray(w)


class TestFitStep:
    def test_loss_decreases(self):
        rng = np.random.default_rng(0)
        theta_true = np.array([1.2, 3.5, 10.0, 60.0, 70.0, 5.0, 6.0, 6.0], np.float32)
        f, y, w = _synthetic(rng, 100, theta_true)
        theta = jnp.zeros((FEATURE_DIM,), jnp.float32)
        lr = jnp.float32(0.01)
        _, loss0 = model.fit_step(f, y, w, theta, lr)
        for _ in range(50):
            theta, loss = model.fit_step(f, y, w, theta, lr)
        assert float(loss) < float(loss0) * 0.5

    def test_converges_to_true_theta(self):
        rng = np.random.default_rng(1)
        theta_true = np.array([1.0, 4.0, 10.0, 60.0, 70.0, 5.0, 6.0, 6.0], np.float32)
        f, y, w = _synthetic(rng, 300, theta_true)
        theta = jnp.asarray(theta_true * 0.5)  # start far off
        lr = jnp.float32(0.02)
        for _ in range(800):
            theta, _ = model.fit_step(f, y, w, theta, lr)
        np.testing.assert_allclose(np.asarray(theta), theta_true, rtol=0.15)

    def test_padding_rows_do_not_bias(self):
        rng = np.random.default_rng(2)
        theta_true = np.array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], np.float32)
        f, y, w = _synthetic(rng, 64, theta_true)
        theta = jnp.asarray(theta_true)
        # at the optimum, gradient must vanish despite the padded rows
        theta2, loss = model.fit_step(f, y, w, theta, jnp.float32(0.1))
        assert float(loss) < 1e-8
        np.testing.assert_allclose(np.asarray(theta2), theta_true, atol=1e-5)

    def test_projection_keeps_parameters_nonnegative(self):
        f = jnp.ones((model.BATCH_ROWS, FEATURE_DIM), jnp.float32)
        y = jnp.full((model.BATCH_ROWS,), -100.0, jnp.float32)
        w = jnp.ones((model.BATCH_ROWS,), jnp.float32)
        theta = jnp.zeros((FEATURE_DIM,), jnp.float32)
        theta2, _ = model.fit_step(f, y, w, theta, jnp.float32(1.0))
        assert np.all(np.asarray(theta2) >= 0.0)


class TestNrmse:
    def test_zero_for_exact(self):
        p = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
        w = jnp.ones((3,), jnp.float32)
        assert float(model.nrmse(p, p, w)) == 0.0

    def test_matches_hand_value(self):
        # predictions off by +1 over mean-2 data: 0.5 (same case as the
        # Rust util::stats test — the two paths are pinned to agree)
        p = jnp.asarray([3.0, 3.0], jnp.float32)
        o = jnp.asarray([2.0, 2.0], jnp.float32)
        w = jnp.ones((2,), jnp.float32)
        assert abs(float(model.nrmse(p, o, w)) - 0.5) < 1e-6

    def test_mask_excludes_rows(self):
        p = jnp.asarray([3.0, 999.0], jnp.float32)
        o = jnp.asarray([2.0, 0.0], jnp.float32)
        w = jnp.asarray([1.0, 0.0], jnp.float32)
        assert abs(float(model.nrmse(p, o, w)) - 0.5) < 1e-6


class TestShapes:
    def test_example_args_shapes(self):
        args = model.example_args()
        assert args["predict"][0].shape == (model.BATCH_ROWS, FEATURE_DIM)
        assert args["fit_step"][4].shape == ()
        assert len(args["nrmse"]) == 3

    def test_predict_output_shape(self):
        f = jnp.zeros((model.BATCH_ROWS, FEATURE_DIM), jnp.float32)
        t = jnp.zeros((FEATURE_DIM,), jnp.float32)
        assert model.predict(f, t).shape == (model.BATCH_ROWS,)
