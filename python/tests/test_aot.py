"""AOT lowering tests: every artifact lowers to parseable HLO text with the
expected entry computation, and the legacy model.hlo.txt alias is emitted."""

import os
import subprocess
import sys

import pytest

from compile.aot import lower_all


class TestLowering:
    @pytest.fixture(scope="class")
    def texts(self):
        return lower_all()

    def test_all_three_artifacts(self, texts):
        assert set(texts) == {"predict", "fit_step", "nrmse"}

    def test_hlo_text_shape_signatures(self, texts):
        # predict: f32[512,8], f32[8] -> tuple(f32[512])
        assert "f32[512,8]" in texts["predict"]
        assert "f32[8]" in texts["predict"]
        # fit_step returns a 2-tuple (theta', loss)
        assert "f32[512,8]" in texts["fit_step"]
        # nrmse takes three vectors
        assert texts["nrmse"].count("f32[512]") >= 3

    def test_entry_computation_present(self, texts):
        for name, text in texts.items():
            assert "ENTRY" in text, f"{name} lacks an entry computation"

    def test_no_custom_calls_in_predict(self, texts):
        # interpret=True must lower the Pallas kernel to plain HLO that the
        # CPU PJRT client can run — no Mosaic custom-calls.
        assert "custom-call" not in texts["predict"].lower().replace(
            "custom_call", "custom-call"
        ) or "mosaic" not in texts["predict"].lower()


class TestCli:
    def test_writes_artifacts(self, tmp_path):
        repo_python = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--outdir", str(tmp_path)],
            cwd=repo_python,
            check=True,
        )
        for f in ["predict.hlo.txt", "fit_step.hlo.txt", "nrmse.hlo.txt",
                  "model.hlo.txt", "manifest.txt"]:
            assert (tmp_path / f).exists(), f
        assert (tmp_path / "predict.hlo.txt").read_text().startswith("Hlo")
