#!/usr/bin/env python3
"""Bench regression gate for ci.sh.

Compares a freshly generated BENCH_sweep.json against the committed
BENCH_baseline.json and fails (exit 1) when any throughput entry regresses
by more than the threshold (default 20%).

Throughput entries are the keys containing "per_sec" — higher is better.
Wall-clock keys (\*_ms) are machine-load noise, and ratio keys
(\*_speedup, \*_pct — e.g. `contend_trace_overhead_pct`, the cost of
attaching a trace sink) are informational; both are reported but never
gated on.

Bootstrap: bench numbers are machine-dependent, so a fresh checkout (or a
baseline still carrying "calibrated": false) cannot be gated against.  In
that case the script rewrites the baseline from the fresh run, marks it
calibrated, and exits 0 with a notice — commit the file to arm the gate
on this machine.

New entries: a throughput key present in the fresh results but absent
from the committed baseline (a PR added a benchmark) is reported as
"new (unadjudicated)" and does not fail the gate — it has no baseline to
regress against.  `--list-new` prints exactly those keys, one per line,
and exits 0 (nothing else on stdout, so it pipes cleanly) — the quick way
to see which keys a PR added (e.g. the `fit_`, `calibrate_`,
`contend_fabric_` and `predict_` families arrived unadjudicated this
way) before deciding to adopt them.  Non-throughput keys a PR adds
(like the trace-overhead pct) never need adjudication — only `per_sec`
keys are gated.

Baseline refresh flow:
  1. `python3 scripts/bench_gate.py BASELINE FRESH --list-new` to see
     what would be adopted;
  2. `./ci.sh --update-baseline` (or `python3 scripts/bench_gate.py
     BASELINE FRESH --update-baseline`) to rewrite the baseline from the
     fresh run — this folds new keys in AND re-anchors every existing
     key, so only do it on an otherwise healthy run;
  3. commit BENCH_baseline.json; from the next run on the new keys are
     gated like every other key.
The same flag is the escape hatch after an intentional slowdown.

Usage: bench_gate.py BASELINE FRESH [--threshold 0.20] [--update-baseline]
                                    [--list-new]
"""

import json
import sys


def throughput_keys(d):
    return sorted(
        k for k, v in d.items() if "per_sec" in k and isinstance(v, (int, float))
    )


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--")]
    if len(args) != 2:
        print(__doc__)
        return 2
    baseline_path, fresh_path = args
    threshold = 0.20
    for a in argv[1:]:
        if a.startswith("--threshold="):
            threshold = float(a.split("=", 1)[1])
    update = "--update-baseline" in argv[1:]
    list_new = "--list-new" in argv[1:]

    try:
        with open(fresh_path) as f:
            fresh = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench gate: cannot read fresh results {fresh_path}: {e}")
        return 1

    baseline = None
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except (OSError, ValueError):
        pass

    if list_new:
        # Unadjudicated keys only, one per line (empty baseline = all new).
        known = baseline or {}
        for k in throughput_keys(fresh):
            if k not in known:
                print(k)
        return 0

    if update or baseline is None or not baseline.get("calibrated", False):
        out = dict(fresh)
        out["calibrated"] = True
        with open(baseline_path, "w") as f:
            json.dump(out, f, indent=1)
            f.write("\n")
        why = (
            "--update-baseline"
            if update
            else "baseline missing or uncalibrated (first run on this machine)"
        )
        print(f"bench gate: wrote {baseline_path} from {fresh_path} ({why});")
        print("bench gate: commit it to arm the regression gate. PASS (bootstrap)")
        return 0

    keys = throughput_keys(baseline)
    if not keys:
        print(f"bench gate: no throughput entries in {baseline_path}")
        return 1
    failures = []
    for k in keys:
        base = float(baseline[k])
        new = float(fresh.get(k, 0.0))
        ratio = new / base if base > 0 else float("inf")
        status = "ok"
        if new < base * (1.0 - threshold):
            status = f"REGRESSION (<{1.0 - threshold:.0%} of baseline)"
            failures.append(k)
        print(f"  {k:<28} baseline {base:>12.1f}  fresh {new:>12.1f}  ({ratio:.2f}x) {status}")
    unadjudicated = [k for k in throughput_keys(fresh) if k not in baseline]
    for k in unadjudicated:
        print(
            f"  {k:<28} baseline {'-':>12}  fresh {float(fresh[k]):>12.1f}  "
            f"new (unadjudicated)"
        )
    if unadjudicated:
        print(
            "bench gate: "
            f"{len(unadjudicated)} new entr{'y' if len(unadjudicated) == 1 else 'ies'} "
            "not in the baseline; run ./ci.sh --update-baseline and commit "
            "BENCH_baseline.json to start gating them"
        )
    if failures:
        print(
            f"bench gate: FAIL — {', '.join(failures)} regressed more than "
            f"{threshold:.0%}; rerun, or ./ci.sh --update-baseline if intentional"
        )
        return 1
    print("bench gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
