#!/usr/bin/env bash
# Run-thread scalability ladder for the machine-accurate contend grid:
# times `repro contend` (CAS, FAA, write over the paper's thread ladder)
# at each run-pool width and prints points/s per rung, so run-level
# scaling is visible — and regressions audible — without the full bench.
# A final pair of rungs times the serial ladder with --steady-state off
# vs on (the periodic fast-forward's wall-clock win).
#
#   scripts/scalability.sh [--arch NAME] [--ops N] [--rungs "1 2 4 8"]
#
#   --arch   architecture to sweep (default ivybridge)
#   --ops    ops per thread per run (default 600)
#   --rungs  run-thread counts to time (default "1 2 4 N" where N = nproc)
#   BIN      override the repro binary (default target/release/repro,
#            built on demand)
set -euo pipefail
cd "$(dirname "$0")/.."

ARCH=ivybridge
OPS=600
RUNGS=""
while [ $# -gt 0 ]; do
    case "$1" in
        --arch)  ARCH="$2";  shift 2 ;;
        --ops)   OPS="$2";   shift 2 ;;
        --rungs) RUNGS="$2"; shift 2 ;;
        *) echo "unknown argument '$1'" >&2; exit 2 ;;
    esac
done

if [ -z "$RUNGS" ]; then
    N=$( (command -v nproc >/dev/null && nproc) || echo 4 )
    RUNGS="1 2 4"
    case " $RUNGS " in *" $N "*) ;; *) RUNGS="$RUNGS $N" ;; esac
fi

BIN="${BIN:-target/release/repro}"
if [ ! -x "$BIN" ]; then
    echo "building $BIN ..." >&2
    cargo build --release
fi

# Points per contend invocation: the paper thread ladder is derived from
# the topology (powers of two below the core count, plus the count).
case "$ARCH" in
    haswell)    PER_OP=3 ;;   # 1 2 4
    ivybridge)  PER_OP=6 ;;   # 1 2 4 8 16 24
    bulldozer)  PER_OP=6 ;;   # 1 2 4 8 16 32
    xeonphi)    PER_OP=7 ;;   # 1 2 4 8 16 32 61
    *) echo "unknown arch '$ARCH'" >&2; exit 2 ;;
esac
POINTS=$((PER_OP * 3))  # cas + faa + write

echo "contend scalability — $ARCH, $OPS ops/thread, $POINTS whole runs per rung"
for R in $RUNGS; do
    START=$(date +%s.%N)
    for OP in cas faa write; do
        "$BIN" contend --arch "$ARCH" --op "$OP" --ops "$OPS" \
            --run-threads "$R" >/dev/null
    done
    END=$(date +%s.%N)
    echo "$START $END $R $POINTS" | awk '{
        dt = $2 - $1; if (dt <= 0) dt = 1e-9;
        printf "  run-threads %-3s %8.2fs   %7.2f points/s\n", $3, dt, $4 / dt
    }'
done

# Steady rung: the same ladder serially, stepwise vs periodic
# fast-forward — the --steady-state wall-clock win without the full
# bench (results are bit-identical; engagement diagnostics silenced).
for MODE in off on; do
    START=$(date +%s.%N)
    for OP in cas faa write; do
        "$BIN" contend --arch "$ARCH" --op "$OP" --ops "$OPS" \
            --run-threads 1 --steady-state "$MODE" >/dev/null 2>&1
    done
    END=$(date +%s.%N)
    echo "$START $END $MODE $POINTS" | awk '{
        dt = $2 - $1; if (dt <= 0) dt = 1e-9;
        printf "  steady-state %-3s %6.2fs   %7.2f points/s\n", $3, dt, $4 / dt
    }'
done
