//! Shared-counter contention study (§5.4, Fig. 8): what happens to a hot
//! FAA counter as threads pile on, across all four testbeds.
//!
//! Run: `cargo run --release --example shared_counter`

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::contention::{paper_thread_counts, OPS_PER_THREAD};
use atomics_repro::sim::event::run_contention;

fn main() {
    println!("Contended FAA bandwidth (one shared counter), GB/s\n");
    for cfg in arch::all() {
        println!("== {} ({} cores, {}) ==", cfg.name, cfg.topology.n_cores, cfg.protocol.name());
        println!("{:>8} {:>12} {:>14} {:>14}", "threads", "FAA [GB/s]", "write [GB/s]", "FAA lat [ns]");
        for n in paper_thread_counts(&cfg) {
            let faa = run_contention(&cfg, n, OpKind::Faa, OPS_PER_THREAD);
            let wr = run_contention(&cfg, n, OpKind::Write, OPS_PER_THREAD);
            println!(
                "{:>8} {:>12.3} {:>14.3} {:>14.1}",
                n, faa.bandwidth_gbs, wr.bandwidth_gbs, faa.mean_latency_ns
            );
        }
        println!();
    }
    println!("Takeaways (§5.4): Intel writes combine and scale; atomics serialize;");
    println!("Xeon Phi collapses on the ring; Bulldozer dips to 8 threads then recovers.");
}
