//! Shared-counter contention study (§5.4, Fig. 8): what happens to a hot
//! FAA counter as threads pile on, across all four testbeds — through the
//! machine-accurate multi-core engine, so each row also explains *why*
//! (line ping-pong, arbitration stalls).
//!
//! Run: `cargo run --release --example shared_counter`

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::contention::{
    paper_thread_counts, run_model, ContentionModel, OPS_PER_THREAD,
};
use atomics_repro::sim::Machine;

fn main() {
    println!("Contended FAA bandwidth (one shared counter), machine-accurate engine\n");
    for cfg in arch::all() {
        println!("== {} ({} cores, {}) ==", cfg.name, cfg.topology.n_cores, cfg.protocol.name());
        println!(
            "{:>8} {:>12} {:>14} {:>9} {:>13}",
            "threads", "FAA [GB/s]", "write [GB/s]", "hops/op", "stall [ns/op]"
        );
        let mut m = Machine::new(cfg.clone());
        for n in paper_thread_counts(&cfg) {
            let faa = run_model(&mut m, ContentionModel::MachineAccurate, n, OpKind::Faa, OPS_PER_THREAD);
            let wr = run_model(&mut m, ContentionModel::MachineAccurate, n, OpKind::Write, OPS_PER_THREAD);
            println!(
                "{:>8} {:>12.3} {:>14.3} {:>9.3} {:>13.1}",
                n,
                faa.bandwidth_gbs,
                wr.bandwidth_gbs,
                faa.total_line_hops() as f64 / faa.total_ops().max(1) as f64,
                faa.mean_stall_ns()
            );
        }
        println!();
    }
    println!("Takeaways (§5.4): Intel writes combine and scale; atomics serialize on");
    println!("line ownership (hops/op → 1, stalls dominate); Xeon Phi collapses on");
    println!("the ring. `--model analytic` via `repro contend` cross-validates.");
}
