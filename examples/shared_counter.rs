//! Shared-counter contention study (§5.4, Fig. 8): what happens to a hot
//! FAA counter as threads pile on, across all four testbeds — through the
//! machine-accurate multi-core engine, so each row also explains *why*
//! (line ping-pong, arbitration stalls). Runs with steady-state
//! fast-forward (DESIGN.md §12) and prints the detected period under each
//! contended row: the cycle the run settles into, its per-period stats,
//! and how much of the run was replayed without cache walks — with
//! bit-identical results to `--steady-state off`.
//!
//! Run: `cargo run --release --example shared_counter`

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::contention::{
    paper_thread_counts, run_model_steady_in, ContentionModel, OPS_PER_THREAD,
};
use atomics_repro::sim::{Machine, RunArena, SteadyMode};

fn main() {
    println!("Contended FAA bandwidth (one shared counter), machine-accurate engine\n");
    for cfg in arch::all() {
        println!("== {} ({} cores, {}) ==", cfg.name, cfg.topology.n_cores, cfg.protocol.name());
        println!(
            "{:>8} {:>12} {:>14} {:>9} {:>13}",
            "threads", "FAA [GB/s]", "write [GB/s]", "hops/op", "stall [ns/op]"
        );
        let mut m = Machine::new(cfg.clone());
        let mut arena = RunArena::new();
        for n in paper_thread_counts(&cfg) {
            let (faa, steady) = run_model_steady_in(
                &mut m,
                &mut arena,
                ContentionModel::MachineAccurate,
                n,
                OpKind::Faa,
                OPS_PER_THREAD,
                SteadyMode::Auto,
            );
            let (wr, _) = run_model_steady_in(
                &mut m,
                &mut arena,
                ContentionModel::MachineAccurate,
                n,
                OpKind::Write,
                OPS_PER_THREAD,
                SteadyMode::Auto,
            );
            println!(
                "{:>8} {:>12.3} {:>14.3} {:>9.3} {:>13.1}",
                n,
                faa.bandwidth_gbs,
                wr.bandwidth_gbs,
                faa.total_line_hops() as f64 / faa.total_ops().max(1) as f64,
                faa.mean_stall_ns()
            );
            if steady.engaged {
                // Per-period stats of the detected cycle: in the contend
                // hammer every event is one retired op, so a period is
                // period_events ops spread over the n threads.
                println!(
                    "{:>8} steady period: {} events / {:.1} ns ({} ops per thread, {:.1} ns/op); {} periods fast-forwarded, {} walks skipped{}",
                    "",
                    steady.period_events,
                    steady.period_ns,
                    steady.period_events / n.max(1),
                    steady.period_ns / steady.period_events.max(1) as f64,
                    steady.periods_fast_forwarded,
                    steady.events_skipped,
                    if steady.aborted { " (aborted, tail stepwise)" } else { "" }
                );
            }
        }
        println!();
    }
    println!("Takeaways (§5.4): Intel writes combine and scale; atomics serialize on");
    println!("line ownership (hops/op → 1, stalls dominate); Xeon Phi collapses on");
    println!("the ring. The steady rows show the fast-forward (DESIGN.md §12) at");
    println!("work: results are bit-identical to `--steady-state off`, only the");
    println!("wall-clock shrinks. `--model analytic` via `repro contend`");
    println!("cross-validates.");
}
