//! What-if architecture explorer: quantify the paper's §6.2 hardware
//! proposals by running the same S/O-state workloads on Bulldozer with the
//! MOESI+OL/SL states (§6.2.1), HT Assist S/O tracking (§6.2.2), and the
//! FastLock relaxed-atomics prefix (§6.2.3) enabled.
//!
//! Run: `cargo run --release --example what_if`

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::latency::LatencyBench;
use atomics_repro::bench::placement::{PrepLocality, PrepState};

fn main() {
    std::env::set_var("FAST", "1");
    let sizes: Vec<usize> = vec![64 << 10, 1 << 20];

    println!("§6.2.1/§6.2.2 — S-state CAS latency on die-local shared lines [ns]");
    println!("(the baseline broadcasts invalidations to remote dies; both fixes suppress them)\n");
    let variants = [
        ("MOESI (shipping Bulldozer)", arch::bulldozer()),
        ("+ OL/SL states", arch::bulldozer_with_extensions(true, false, false)),
        ("+ HT Assist tracking", arch::bulldozer_with_extensions(false, true, false)),
        ("+ both", arch::bulldozer_with_extensions(true, true, false)),
    ];
    for locality in [PrepLocality::SharedL2, PrepLocality::OnChip] {
        println!("  data owned {}:", locality.label());
        for (name, cfg) in &variants {
            let mut bench = LatencyBench::new(OpKind::Cas, PrepState::S, locality);
            bench.sharer = atomics_repro::bench::placement::SharerPlacement::SameDie;
            let vals: Vec<f64> = sizes
                .iter()
                .filter_map(|&s| bench.run_once(cfg, s))
                .collect();
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            println!("    {:<28} {:>7.1} ns", name, mean);
        }
        println!();
    }

    println!("§6.2.3 — FastLock: FAA bandwidth to independent lines [GB/s]");
    println!("(the lock prefix drains the store buffer; FastLock only drains overlaps)\n");
    for (name, cfg) in [
        ("lock prefix (baseline)", arch::bulldozer()),
        ("FastLock prefix", arch::bulldozer_with_extensions(false, false, true)),
    ] {
        let vals: Vec<f64> = sizes
            .iter()
            .map(|&s| atomics_repro::bench::bandwidth::mixed_stream_bandwidth(&cfg, s))
            .collect();
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        println!("  {:<28} {:>7.2} GB/s", name, mean);
    }
}
