//! What-if architecture explorer: quantify the paper's §6.2 hardware
//! proposals by running the same S/O-state workloads on Bulldozer with the
//! MOESI+OL/SL states (§6.2.1), HT Assist S/O tracking (§6.2.2), and the
//! FastLock relaxed-atomics prefix (§6.2.3) enabled — then sketch a
//! cross-architecture what-if through the serving engine's batch API.
//!
//! Fast mode is an explicit API choice here
//! ([`report::sweep_sizes_with`]), not an env-var mutation: the example
//! asks for the reduced sweep directly instead of flipping `FAST` for the
//! whole process.
//!
//! Run: `cargo run --release --example what_if`

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::latency::LatencyBench;
use atomics_repro::bench::placement::{PrepLocality, PrepState};
use atomics_repro::model::query::ModelState;
use atomics_repro::report;
use atomics_repro::sim::timing::Level;
use atomics_repro::sim::topology::Distance;
use atomics_repro::{ArchId, PredictEngine, PredictRequest, QueryBuilder};

fn main() {
    // explicit fast-mode: take the head of the reduced figure sweep
    let sizes: Vec<usize> = report::sweep_sizes_with(true).into_iter().take(2).collect();

    println!("§6.2.1/§6.2.2 — S-state CAS latency on die-local shared lines [ns]");
    println!("(the baseline broadcasts invalidations to remote dies; both fixes suppress them)\n");
    let variants = [
        ("MOESI (shipping Bulldozer)", arch::bulldozer()),
        ("+ OL/SL states", arch::bulldozer_with_extensions(true, false, false)),
        ("+ HT Assist tracking", arch::bulldozer_with_extensions(false, true, false)),
        ("+ both", arch::bulldozer_with_extensions(true, true, false)),
    ];
    for locality in [PrepLocality::SharedL2, PrepLocality::OnChip] {
        println!("  data owned {}:", locality.label());
        for (name, cfg) in &variants {
            let mut bench = LatencyBench::new(OpKind::Cas, PrepState::S, locality);
            bench.sharer = atomics_repro::bench::placement::SharerPlacement::SameDie;
            let vals: Vec<f64> = sizes
                .iter()
                .filter_map(|&s| bench.run_once(cfg, s))
                .collect();
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            println!("    {:<28} {:>7.1} ns", name, mean);
        }
        println!();
    }

    println!("§6.2.3 — FastLock: FAA bandwidth to independent lines [GB/s]");
    println!("(the lock prefix drains the store buffer; FastLock only drains overlaps)\n");
    for (name, cfg) in [
        ("lock prefix (baseline)", arch::bulldozer()),
        ("FastLock prefix", arch::bulldozer_with_extensions(false, false, true)),
    ] {
        let vals: Vec<f64> = sizes
            .iter()
            .map(|&s| atomics_repro::bench::bandwidth::mixed_stream_bandwidth(&cfg, s))
            .collect();
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        println!("  {:<28} {:>7.2} GB/s", name, mean);
    }

    // Model-level what-if through the serving API: where would a
    // contended shared-line CAS land on each testbed? One batch, one
    // engine, the same backend `repro predict` serves.
    println!("\nmodel what-if — shared-line CAS (L3-or-last-level, die-local sharers) [ns]");
    let mut engine = PredictEngine::shipped();
    let reqs: Vec<PredictRequest> = ArchId::ALL
        .iter()
        .map(|&a| {
            let level = if a.config().has_l3() { Level::L3 } else { Level::L2 };
            let query = QueryBuilder::new(OpKind::Cas, ModelState::S)
                .level(level)
                .distance(Distance::SameDie)
                .build()
                .expect("valid query");
            PredictRequest::new(a, query)
        })
        .collect();
    let responses = engine.predict_batch(&reqs).expect("grid points are valid");
    for r in &responses {
        println!(
            "  {:<11} {:>7.1} ns  ({:>5.2} GB/s over distinct lines)",
            r.arch.label(),
            r.latency_ns,
            r.bandwidth_gbs
        );
    }
}
