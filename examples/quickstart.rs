//! Quickstart: measure the latency of CAS vs a plain read on the simulated
//! Haswell testbed, across the memory hierarchy — the paper's Figure 2 in
//! five lines of API.
//!
//! Run: `cargo run --release --example quickstart`

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::latency::LatencyBench;
use atomics_repro::bench::placement::{PrepLocality, PrepState};

fn main() {
    let cfg = arch::haswell();
    println!("CAS vs read latency on {} (M state, local buffer)\n", cfg.name);
    println!("{:>8} {:>10} {:>10} {:>8}", "buffer", "read [ns]", "CAS [ns]", "Δ [ns]");
    for size in [16 << 10, 128 << 10, 4 << 20, 32 << 20] {
        let read = LatencyBench::new(OpKind::Read, PrepState::M, PrepLocality::Local)
            .run_once(&cfg, size)
            .unwrap();
        let cas = LatencyBench::new(OpKind::Cas, PrepState::M, PrepLocality::Local)
            .run_once(&cfg, size)
            .unwrap();
        println!(
            "{:>8} {:>10.2} {:>10.2} {:>8.2}",
            atomics_repro::report::human_size(size),
            read,
            cas,
            cas - read
        );
    }
    println!("\nThe gap is E(CAS) ≈ {:.1} ns at every level — the paper's Eq. 1.", cfg.timing.e_cas);
}
