//! Quickstart: the paper's two headline comparisons in a dozen lines of
//! the post-sweep-refactor API —
//!
//! 1. latency of CAS vs a plain read across the memory hierarchy on the
//!    simulated Haswell testbed (Fig. 2), via `LatencyBench::run_once`
//!    (the same entry point the `sweep::Workload` trait and the parallel
//!    `SweepExecutor` drive for the full figure grids), and
//! 2. contended same-line FAA (Fig. 8) through the machine-accurate
//!    multi-core scheduler `sim::multicore`, which also says *why*
//!    bandwidth collapses (line ping-pong, arbitration stalls).
//!
//! Run: `cargo run --release --example quickstart`

use atomics_repro::arch;
use atomics_repro::atomics::OpKind;
use atomics_repro::bench::contention::{run_model, ContentionModel};
use atomics_repro::bench::latency::LatencyBench;
use atomics_repro::bench::placement::{PrepLocality, PrepState};
use atomics_repro::sim::Machine;

fn main() {
    let cfg = arch::haswell();
    println!("CAS vs read latency on {} (M state, local buffer)\n", cfg.name);
    println!("{:>8} {:>10} {:>10} {:>8}", "buffer", "read [ns]", "CAS [ns]", "Δ [ns]");
    for size in [16 << 10, 128 << 10, 4 << 20, 32 << 20] {
        let read = LatencyBench::new(OpKind::Read, PrepState::M, PrepLocality::Local)
            .run_once(&cfg, size)
            .unwrap();
        let cas = LatencyBench::new(OpKind::Cas, PrepState::M, PrepLocality::Local)
            .run_once(&cfg, size)
            .unwrap();
        println!(
            "{:>8} {:>10.2} {:>10.2} {:>8.2}",
            atomics_repro::report::human_size(size),
            read,
            cas,
            cas - read
        );
    }
    println!("\nThe gap is E(CAS) ≈ {:.1} ns at every level — the paper's Eq. 1.", cfg.timing.e_cas);

    println!("\nContended FAA on one line (machine-accurate engine, §5.4)\n");
    println!("{:>7} {:>8} {:>9} {:>12}", "threads", "GB/s", "hops/op", "stall ns/op");
    let mut m = Machine::new(cfg);
    for threads in [1usize, 2, 4] {
        let p = run_model(&mut m, ContentionModel::MachineAccurate, threads, OpKind::Faa, 2000);
        println!(
            "{:>7} {:>8.3} {:>9.3} {:>12.1}",
            threads,
            p.bandwidth_gbs,
            p.total_line_hops() as f64 / p.total_ops() as f64,
            p.mean_stall_ns()
        );
    }
    println!("\nBandwidth falls as the line ping-pongs — `repro contend --stats` for more.");
}
