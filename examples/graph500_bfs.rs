//! Graph500 BFS case study (§6.1, Fig. 10b): CAS vs SWP claim protocols on
//! Kronecker graphs, with tree validation against a sequential reference.
//!
//! Run: `cargo run --release --example graph500_bfs [scale] [threads]`

use atomics_repro::arch;
use atomics_repro::graph::bfs::validate_tree;
use atomics_repro::graph::{kronecker_edges, parallel_bfs, BfsMode, Csr};
use atomics_repro::sim::Machine;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let scale: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(14);
    let threads: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    println!(
        "scale {scale}: {} vertices, {} edges, {threads} threads\n",
        1u64 << scale,
        16 * (1u64 << scale)
    );
    let csr = Csr::from_edges(1 << scale, &kronecker_edges(scale, 0xBF5));
    let root = csr.first_non_isolated().expect("graph has edges");

    for mode in [BfsMode::Cas, BfsMode::Swp] {
        let mut m = Machine::new(arch::haswell());
        let r = parallel_bfs(&mut m, &csr, root, threads, mode);
        validate_tree(&csr, root, &r.parent).expect("valid BFS tree");
        println!(
            "{:<4} {:>8.1} MTEPS   {:>9} edges   {:>8.2} ms virtual   {:>6} wasted claims   ({} sim accesses)",
            mode.label(),
            r.mteps,
            r.edges_scanned,
            r.elapsed_ns / 1e6,
            r.wasted_claims,
            m.stats.accesses,
        );
    }
    println!("\nSWP > CAS in MTEPS: the failed-CAS retry loop is pure wasted work (§6.1).");
}
