//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! Pipeline (recorded in EXPERIMENTS.md):
//!   1. L3 simulator — run the latency benchmark suite on all four testbeds
//!      (the paper's measurement campaign, §5.1).
//!   2. Featurize every measured point (Eq. 1–8 as `F·θ`).
//!   3. PJRT — load the AOT JAX/Pallas artifacts and *fit* θ per testbed by
//!      iterating the `fit_step` executable (gradient descent on masked
//!      MSE); this regenerates Table 2 from measurements.
//!   4. PJRT — batch-predict all points through the Pallas-kernel HLO and
//!      validate with the `nrmse` executable (Eq. 12, §5's 10% protocol).
//!   5. L3 workload — run the Graph500 BFS case study (Fig. 10b).
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use atomics_repro::arch;
use atomics_repro::coordinator::dataset::{collect_latency_dataset, fit_sizes};
use atomics_repro::coordinator::fit::{fit_theta, FitCfg};
use atomics_repro::coordinator::scatter;
use atomics_repro::graph::bfs::validate_tree;
use atomics_repro::graph::{kronecker_edges, parallel_bfs, BfsMode, Csr};
use atomics_repro::model::params::{Theta, THETA_DIM};
use atomics_repro::runtime::{Batch, Runtime, BATCH_ROWS};
use atomics_repro::sim::Machine;

fn main() -> anyhow::Result<()> {
    let t_start = std::time::Instant::now();

    // ---- 1. measurement campaign on the simulator (parallel per arch) ----
    println!("[1/5] running the latency benchmark campaign on 4 testbeds...");
    let datasets = scatter(arch::all(), |cfg| {
        let ds = collect_latency_dataset(&cfg, &fit_sizes(&cfg));
        (cfg, ds)
    });
    for (cfg, ds) in &datasets {
        println!("   {:<11} {} measured points", cfg.name, ds.len());
    }

    // ---- 2/3. PJRT fit loop per testbed (Table 2) ----
    println!("[2/5] loading AOT artifacts (predict/fit_step/nrmse) via PJRT...");
    let rt = Runtime::load(Runtime::default_dir())?;

    println!("[3/5] fitting Table 2 parameters through the fit_step executable...");
    let mut fitted = Vec::new();
    for (cfg, ds) in &datasets {
        let report = fit_theta(&rt, cfg.name, ds, Theta::from_config(cfg), FitCfg::default())?;
        println!(
            "   {:<11} loss {:>9.3} after {:>4} epochs ({} pts)",
            report.arch, report.final_loss, report.iterations, report.n_points
        );
        fitted.push(report);
    }
    println!("   Table 2 (paper vs fitted):");
    for r in &fitted {
        print!("   {:<11}", r.arch);
        for i in 0..THETA_DIM {
            let s = r.seed_theta.to_vec()[i];
            let f = r.theta.to_vec()[i];
            if s > 0.0 {
                print!(" {}={:.1}/{:.1}", Theta::NAMES[i], s, f);
            }
        }
        println!();
    }

    // ---- 4. batched prediction + NRMSE through PJRT ----
    println!("[4/5] validating: batched Pallas predictions + NRMSE executable...");
    for ((cfg, ds), fit) in datasets.iter().zip(&fitted) {
        let rows: Vec<([f64; THETA_DIM], f64)> =
            ds.iter().map(|d| (d.features, d.measured_ns)).collect();
        let theta32: [f32; THETA_DIM] = std::array::from_fn(|i| fit.theta.to_vec()[i] as f32);
        let mut total_nrmse = 0.0;
        let batches = Batch::pack(&rows);
        for b in &batches {
            let pred = rt.predict(&b.features, &theta32)?;
            let mut obs = vec![0f32; BATCH_ROWS];
            obs.copy_from_slice(&b.targets);
            let v = rt.nrmse(&pred, &obs, &b.mask)?;
            total_nrmse += f64::from(v);
        }
        let nrmse = total_nrmse / batches.len() as f64;
        println!(
            "   {:<11} NRMSE {:>5.1}% {}",
            cfg.name,
            nrmse * 100.0,
            if nrmse > 0.10 { "(>10% — discussed in EXPERIMENTS.md)" } else { "(within the paper's 10% protocol)" }
        );
    }

    // ---- 5. the BFS case study ----
    println!("[5/5] Graph500 BFS case study (scale 14, 4 threads, Haswell)...");
    let csr = Csr::from_edges(1 << 14, &kronecker_edges(14, 0xBF5));
    let root = csr.first_non_isolated().unwrap();
    for mode in [BfsMode::Cas, BfsMode::Swp] {
        let mut m = Machine::new(arch::haswell());
        let r = parallel_bfs(&mut m, &csr, root, 4, mode);
        validate_tree(&csr, root, &r.parent).expect("valid BFS tree");
        println!(
            "   {:<4} {:>8.1} MTEPS ({} wasted claims)",
            mode.label(),
            r.mteps,
            r.wasted_claims
        );
    }

    println!(
        "\nend-to-end OK in {:.1}s — all layers composed: simulator -> featurizer -> PJRT fit/predict/NRMSE -> workload",
        t_start.elapsed().as_secs_f64()
    );
    Ok(())
}
